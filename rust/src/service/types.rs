//! Typed request/response surface of the service API: what callers build
//! ([`GenRequest`]), what they stream back ([`GenEvent`] /
//! [`Completion`]), and the typed submission-rejection reasons
//! ([`SubmitError`]).

use crate::request::{PriorityClass, RequestId, SamplingParams};
use crate::tokenizer;
use anyhow::{bail, Result};
use std::fmt;

/// Why a submission was refused at the service boundary. Carried inside
/// the `anyhow::Error` returned by `Service::submit` — downcast to tell a
/// drain-window rejection apart from a validation failure:
///
/// ```ignore
/// match service.submit(req) {
///     Err(e) if e.downcast_ref::<SubmitError>()
///         == Some(&SubmitError::Draining) => { /* back off / reroute */ }
///     other => { /* … */ }
/// }
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The service is draining: in-flight work finishes, new work is
    /// refused.
    Draining,
    /// The service has shut down (or its worker died).
    ShutDown,
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::Draining => {
                write!(f, "service is draining — new submissions rejected")
            }
            SubmitError::ShutDown => write!(f, "service is shut down"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// A typed generation request, the one submission format for every entry
/// point (embedded [`super::Service`], TCP server, examples, benches).
#[derive(Debug, Clone)]
pub struct GenRequest {
    /// Prompt token ids. Use [`GenRequest::from_text`] to go through the
    /// byte tokenizer.
    pub prompt_tokens: Vec<i32>,
    /// Generation budget; the request finishes after this many new tokens.
    pub max_new_tokens: u32,
    /// Sampling parameters, validated at submission and plumbed through
    /// to the engine (current engines decode greedily — see DESIGN.md).
    pub sampling: SamplingParams,
    /// Priority class for class-weighted admission.
    pub class: PriorityClass,
    /// Relative deadline in seconds from acceptance: if the request is
    /// still waiting for admission when it expires, it is shed and the
    /// stream ends with [`GenEvent::Error`]. `None` = wait forever.
    pub deadline: Option<f64>,
}

impl GenRequest {
    pub fn new(prompt_tokens: Vec<i32>, max_new_tokens: u32) -> Self {
        GenRequest {
            prompt_tokens,
            max_new_tokens,
            sampling: SamplingParams::default(),
            class: PriorityClass::default(),
            deadline: None,
        }
    }

    /// Build from UTF-8 text via the byte tokenizer.
    pub fn from_text(prompt: &str, max_new_tokens: u32) -> Self {
        Self::new(tokenizer::encode(prompt), max_new_tokens)
    }

    pub fn with_class(mut self, class: PriorityClass) -> Self {
        self.class = class;
        self
    }

    pub fn with_sampling(mut self, sampling: SamplingParams) -> Self {
        self.sampling = sampling;
        self
    }

    /// Shed the request if it is still unadmitted `seconds` after
    /// acceptance.
    pub fn with_deadline(mut self, seconds: f64) -> Self {
        self.deadline = Some(seconds);
        self
    }

    pub fn validate(&self) -> Result<()> {
        if self.prompt_tokens.is_empty() {
            bail!("prompt_tokens must not be empty");
        }
        if self.max_new_tokens == 0 {
            bail!("max_new_tokens must be >= 1");
        }
        if let Some(d) = self.deadline {
            if !d.is_finite() || d <= 0.0 {
                bail!("deadline must be a positive number of seconds");
            }
        }
        self.sampling.validate()
    }
}

/// One event on a submission's stream. Exactly one terminal event
/// ([`Done`](GenEvent::Done) / [`Error`](GenEvent::Error) /
/// [`Cancelled`](GenEvent::Cancelled)) ends every stream.
#[derive(Debug, Clone)]
pub enum GenEvent {
    /// The request entered the scheduler's waiting queue.
    Accepted { id: RequestId, class: PriorityClass },
    /// One generated token.
    Token { id: RequestId, token: i32, text: String },
    /// Full budget generated. Latencies are seconds since acceptance.
    Done {
        id: RequestId,
        text: String,
        n_tokens: u32,
        ttft: f64,
        e2e: f64,
    },
    /// Terminal failure (rejected, deadline exceeded, engine error).
    Error { id: RequestId, message: String },
    /// The request was cancelled; its KV blocks were freed.
    Cancelled { id: RequestId },
}

impl GenEvent {
    pub fn id(&self) -> RequestId {
        match self {
            GenEvent::Accepted { id, .. }
            | GenEvent::Token { id, .. }
            | GenEvent::Done { id, .. }
            | GenEvent::Error { id, .. }
            | GenEvent::Cancelled { id } => *id,
        }
    }

    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            GenEvent::Done { .. }
                | GenEvent::Error { .. }
                | GenEvent::Cancelled { .. }
        )
    }
}

/// Collected result of a completed stream (see
/// [`super::SubmissionHandle::wait`]).
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: RequestId,
    pub text: String,
    /// Streamed token ids in order.
    pub tokens: Vec<i32>,
    pub n_tokens: u32,
    /// Time to first token, seconds since acceptance.
    pub ttft: f64,
    /// End-to-end latency, seconds since acceptance.
    pub e2e: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_text_encodes_prompt() {
        let r = GenRequest::from_text("hi", 4);
        assert_eq!(r.prompt_tokens.len(), 3); // BOS + 2 bytes
        assert_eq!(r.max_new_tokens, 4);
        assert_eq!(r.class, PriorityClass::Standard);
    }

    #[test]
    fn builders_and_validation() {
        let r = GenRequest::new(vec![1, 2], 8)
            .with_class(PriorityClass::Interactive)
            .with_deadline(2.0);
        assert!(r.validate().is_ok());
        assert_eq!(r.class, PriorityClass::Interactive);
        assert_eq!(r.deadline, Some(2.0));

        assert!(GenRequest::new(vec![1], 0).validate().is_err());
        assert!(GenRequest::new(vec![], 4).validate().is_err(),
                "empty prompts are rejected at submission");
        let mut bad = GenRequest::new(vec![1], 1);
        bad.deadline = Some(-1.0);
        assert!(bad.validate().is_err());
        let mut bad = GenRequest::new(vec![1], 1);
        bad.sampling.top_p = 2.0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn submit_error_downcasts_from_anyhow() {
        let e = anyhow::Error::new(SubmitError::Draining);
        assert_eq!(e.downcast_ref::<SubmitError>(),
                   Some(&SubmitError::Draining));
        assert!(e.to_string().contains("draining"), "{e}");
        let e = anyhow::Error::new(SubmitError::ShutDown);
        assert!(e.to_string().contains("shut down"), "{e}");
    }

    #[test]
    fn event_terminality() {
        let done = GenEvent::Done {
            id: 3,
            text: String::new(),
            n_tokens: 0,
            ttft: 0.0,
            e2e: 0.0,
        };
        assert!(done.is_terminal());
        assert_eq!(done.id(), 3);
        let tok = GenEvent::Token { id: 4, token: 1, text: String::new() };
        assert!(!tok.is_terminal());
        assert!(GenEvent::Cancelled { id: 5 }.is_terminal());
        assert!(GenEvent::Error { id: 6, message: String::new() }
            .is_terminal());
        assert!(!GenEvent::Accepted { id: 7, class: PriorityClass::Batch }
            .is_terminal());
    }
}
