//! First-class service API — the one public entry point for running
//! inference (Fig. 1's router → scheduler → engine path, embeddable).
//!
//! ```text
//! ServiceBuilder::new(model, hardware)   // or .engine(|| PjrtEngine…)
//!     .policy(PolicyKind::Combined)
//!     .d_sla(0.05)
//!     .build()?                          // spawns the engine-loop thread
//!     .submit(GenRequest::from_text("hello", 32)
//!         .with_class(PriorityClass::Interactive)
//!         .with_deadline(2.0))?          // → SubmissionHandle
//! ```
//!
//! The [`SubmissionHandle`] streams [`GenEvent`]s (accepted → token* →
//! done | error | cancelled) and supports [`SubmissionHandle::cancel`],
//! which frees the request's KV blocks mid-flight. Admission inside the
//! scheduler is priority-aware: per-class queues interleaved by smooth
//! weighted round-robin under the controller's `b_t`, with deadline-based
//! shedding of expired waiters. [`Service::snapshot`] exposes the live
//! per-class queue depths, KV block accounting, and the controller label.
//!
//! The control plane is live: [`Service::reconfigure`] hot-swaps the
//! batching controller under the scheduler loop (telemetry and in-flight
//! work carry over), and [`Service::drain`] stops admissions — further
//! submissions fail with [`SubmitError::Draining`] — and resolves once
//! every in-flight request has reached its terminal event.
//!
//! Scale-out lives one layer up: [`replica::ReplicaSet`] puts one
//! submission front door over N `Service` replicas with pluggable
//! routing ([`replica::RoutePolicy`]) and first-class rolling restarts
//! built on [`Service::drain`] + [`Service::reopen`]. Above that sits
//! the fleet layer ([`fleet`]): heterogeneous
//! [`ReplicaProfile`](crate::config::ReplicaProfile)s per replica
//! (declared via [`ServiceBuilder::profile`]), capability-aware routing,
//! and an SLA-driven autoscaler ([`fleet::SlaAutoscaler`]) that spawns
//! and retires replicas through the same zero-loss drain/reopen
//! primitives.
//!
//! The TCP frontend ([`crate::server`]) is a thin protocol adapter over
//! this module (including the v2 admin ops `stats` / `set_policy` /
//! `drain`); the wire format is documented there and in DESIGN.md.

pub mod fleet;
pub mod replica;
pub mod types;

pub use crate::request::{PriorityClass, SamplingParams};
pub use fleet::{Fleet, FleetController, FleetDirective, FleetLogEntry,
                FleetObservation, FleetStats, SlaAutoscaler};
pub use replica::{Health, HealthPolicy, HealthTracker, ReplicaLoad,
                  ReplicaSet, RollingError, RouteKey, RoutePolicy};
pub use types::{Completion, GenEvent, GenRequest, SubmitError};

use crate::config::{HardwareSpec, ModelSpec, PolicyKind, ReplicaProfile,
                    SchedulerConfig};
use crate::engine::sim::SimEngine;
use crate::engine::Engine;
use crate::request::{FinishReason, Request, RequestId};
use crate::scheduler::Scheduler;
use crate::tokenizer;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::Duration;

type EngineBuilderFn = Box<dyn FnOnce() -> Result<Box<dyn Engine>> + Send>;

/// Control messages into the engine-loop thread.
enum Command {
    Submit { request: Request, events: Sender<GenEvent> },
    Cancel(RequestId),
    /// Hot-swap the batching controller; `ack` carries the new label.
    SetPolicy { kind: PolicyKind, ack: Sender<Result<String>> },
    /// Register a drain waiter, resolved when in-flight work is done.
    Drain { done: Sender<()> },
    Shutdown,
}

/// Builds a [`Service`]. `new(model, hardware)` defaults to the simulated
/// engine over those specs with η derived from the hardware's KV budget;
/// `.engine(...)` swaps in a real engine (the builder closure runs on the
/// service thread because PJRT handles are not `Send`).
///
/// ```
/// use dynabatch::config::presets::{cpu_host, tiny_real};
/// use dynabatch::service::{GenRequest, PriorityClass, ServiceBuilder};
///
/// let service = ServiceBuilder::new(tiny_real(), cpu_host())
///     .eta_tokens(100_000)
///     .build()?; // spawns the engine-loop thread (simulated engine)
/// let done = service
///     .submit(GenRequest::from_text("hello", 4)
///         .with_class(PriorityClass::Interactive))?
///     .wait()?;
/// assert_eq!(done.n_tokens, 4);
/// service.shutdown();
/// # Ok::<(), anyhow::Error>(())
/// ```
pub struct ServiceBuilder {
    model: ModelSpec,
    hardware: HardwareSpec,
    cfg: SchedulerConfig,
    eta_tokens: Option<u64>,
    swap_tokens: u64,
    prior_in: f64,
    prior_out: f64,
    engine: Option<EngineBuilderFn>,
    profile: Option<ReplicaProfile>,
    start_paused: bool,
    id_start: u64,
    id_stride: u64,
}

impl ServiceBuilder {
    pub fn new(model: ModelSpec, hardware: HardwareSpec) -> Self {
        ServiceBuilder {
            model,
            hardware,
            cfg: SchedulerConfig::default(),
            eta_tokens: None,
            swap_tokens: 0,
            prior_in: 64.0,
            prior_out: 64.0,
            engine: None,
            profile: None,
            start_paused: false,
            id_start: 1,
            id_stride: 1,
        }
    }

    pub fn policy(mut self, policy: PolicyKind) -> Self {
        self.cfg.policy = policy;
        self
    }

    /// Replace the whole scheduler config (policy, b bounds, SLA, …).
    pub fn config(mut self, cfg: SchedulerConfig) -> Self {
        self.cfg = cfg;
        self
    }

    pub fn d_sla(mut self, seconds: f64) -> Self {
        self.cfg.d_sla = Some(seconds);
        self
    }

    /// Use a custom engine instead of the default simulator.
    pub fn engine<F>(mut self, engine_builder: F) -> Self
    where
        F: FnOnce() -> Result<Box<dyn Engine>> + Send + 'static,
    {
        self.engine = Some(Box::new(engine_builder));
        self
    }

    /// Override η (KV token capacity); the default derives it from the
    /// hardware's KV budget for the model.
    pub fn eta_tokens(mut self, eta: u64) -> Self {
        self.eta_tokens = Some(eta);
        self
    }

    pub fn swap_tokens(mut self, tokens: u64) -> Self {
        self.swap_tokens = tokens;
        self
    }

    /// Opt into the ref-counted prefix cache: admission-time allocations
    /// walk the radix tree over prompt token chunks and share KV blocks
    /// for matched prefixes (see [`crate::kv`]). Off by default — the
    /// scheduler is then bit-identical to the no-sharing one.
    pub fn prefix_cache(mut self, on: bool) -> Self {
        self.cfg.prefix_cache = on;
        self
    }

    /// Deploy this replica under a [`ReplicaProfile`]: the resolved η
    /// (KV token capacity — explicit or hardware-derived) is scaled by
    /// the profile's `kv_scale`, and the default simulated engine runs
    /// at the profile's decode/prefill speeds
    /// ([`SimEngine::with_profile`]). A custom `.engine(...)` closure
    /// wins over the profile's timing but the KV scaling still applies.
    /// The profile's name, decode speed and cost unit are surfaced in
    /// [`ServiceSnapshot`] so routers and the fleet controller can tell
    /// heterogeneous replicas apart.
    pub fn profile(mut self, profile: ReplicaProfile) -> Self {
        self.profile = Some(profile);
        self
    }

    /// Seed the length estimators until real samples arrive.
    pub fn priors(mut self, prior_in: f64, prior_out: f64) -> Self {
        self.prior_in = prior_in;
        self.prior_out = prior_out;
        self
    }

    /// Start with the stepping loop paused (submissions and cancels are
    /// still processed); call [`Service::resume`] to begin serving. Useful
    /// for deterministic tests and staged warm-up.
    pub fn paused(mut self, paused: bool) -> Self {
        self.start_paused = paused;
        self
    }

    /// Carve this service's request-id namespace out of a shared id
    /// space: ids are `start, start+stride, start+2·stride, …`. A
    /// [`replica::ReplicaSet`] gives replica `k` of `n` the namespace
    /// `(k+1, n)`, so ids are disjoint across the set and a cancel
    /// routes to its replica in O(1) (`(id-1) mod n`). The default
    /// `(1, 1)` is the standalone single-service id space.
    pub fn request_ids(mut self, start: u64, stride: u64) -> Self {
        assert!(start >= 1 && stride >= 1,
                "request-id namespace needs start >= 1 and stride >= 1");
        self.id_start = start;
        self.id_stride = stride;
        self
    }

    pub fn build(self) -> Result<Service> {
        self.model.validate()?;
        self.hardware.validate()?;
        self.cfg.validate().context("service scheduler config")?;
        let profiled = self.profile.is_some();
        let profile = match self.profile {
            Some(p) => {
                p.validate().context("replica profile")?;
                p
            }
            None => ReplicaProfile::baseline(),
        };
        let base_eta = self.eta_tokens.unwrap_or_else(|| {
            self.hardware.kv_budget(&self.model)
                / self.model.kv_bytes_per_token().max(1)
        });
        // η is the baseline capacity; the profile scales it (bigger or
        // smaller KV pool than the anchoring node).
        let eta = ((base_eta as f64) * profile.kv_scale).round() as u64;
        if eta < self.cfg.block_tokens as u64 {
            bail!(
                "KV budget of {eta} tokens cannot hold a single block — \
                 hardware too small for '{}'",
                self.model.name
            );
        }
        let sched = Scheduler::new(
            self.cfg,
            eta,
            self.swap_tokens,
            self.prior_in,
            self.prior_out,
        );
        let engine = match self.engine {
            Some(f) => f,
            None if profiled => {
                let (m, h) = (self.model, self.hardware);
                let p = profile.clone();
                Box::new(move || {
                    Ok(Box::new(SimEngine::with_profile(&m, &h, &p))
                        as Box<dyn Engine>)
                })
            }
            None => {
                let (m, h) = (self.model, self.hardware);
                Box::new(move || {
                    Ok(Box::new(SimEngine::new(&m, &h)) as Box<dyn Engine>)
                })
            }
        };
        Service::spawn(engine, sched, &profile, self.start_paused,
                       self.id_start, self.id_stride)
    }
}

/// Point-in-time view of the serving loop, refreshed every iteration —
/// per-class queue depths plus the KV block accounting tests assert
/// against (e.g. "cancel freed its blocks").
#[derive(Debug, Clone, Default)]
pub struct ServiceSnapshot {
    pub running: u32,
    /// Fresh requests awaiting admission (== Σ `waiting_by_class`).
    pub waiting: u32,
    /// Waiting depth per class, indexed by [`PriorityClass::rank`].
    pub waiting_by_class: [u32; PriorityClass::COUNT],
    /// Preempted requests queued to resume (not part of `waiting`).
    pub resuming: u32,
    pub kv_used_tokens: u64,
    pub kv_free_blocks: usize,
    pub kv_total_blocks: usize,
    /// Logical tokens served from shared prefix blocks (0 unless the
    /// prefix cache is enabled; see [`ServiceBuilder::prefix_cache`]).
    pub kv_shared_tokens: u64,
    /// Lifetime prefix-cache hit rate over eligible prompt chunks (0.0
    /// before any lookup or when the cache is disabled).
    pub prefix_hit_rate: f64,
    /// Lifetime padded (wasted) prefill tokens under rectangular-kernel
    /// accounting (0 unless the scheduler runs with
    /// `SchedulerConfig::padded_prefill`).
    pub prefill_padded_tokens: u64,
    /// padded / (real + padded) prefill tokens (0.0 with accounting
    /// off) — "is padding eating my throughput?" in one gauge.
    pub padding_waste: f64,
    pub b_t: u32,
    /// Label of the live controller (changes on `reconfigure`).
    pub controller: String,
    pub steps: u64,
    pub finished: u64,
    pub rejected: u64,
    pub shed: u64,
    pub cancelled: u64,
    /// Controller hot-swaps applied so far.
    pub reconfigs: u64,
    /// True once `drain` has been requested.
    pub draining: bool,
    /// Recent decode-latency p50 attributed per class (seconds, indexed
    /// by [`PriorityClass::rank`]; 0.0 until the class has decoded). A
    /// step's latency is attributed to every class in its decode batch.
    pub class_lat_p50: [f64; PriorityClass::COUNT],
    /// Recent per-class decode-latency p95 (seconds) — the router's
    /// per-class SLA headroom signal and the v2 `stats` payload.
    pub class_lat_p95: [f64; PriorityClass::COUNT],
    /// Live per-class TTFT p95 (seconds; 0.0 until the class has seen a
    /// first token). Fed by the scheduler the moment a request's first
    /// token lands, so TTFT-driven routing and autoscaling never wait
    /// for request completion.
    pub class_ttft_p95: [f64; PriorityClass::COUNT],
    /// Name of the [`ReplicaProfile`] this replica was deployed under
    /// ("baseline" when none was set).
    pub profile: String,
    /// The profile's relative decode speed (1.0 = anchoring node).
    pub decode_speed: f64,
    /// The profile's relative cost per replica-second.
    pub cost_unit: f64,
}

struct Shared {
    shutdown: AtomicBool,
    paused: AtomicBool,
    draining: AtomicBool,
    /// Submissions past the draining gate but not yet in the control
    /// channel. Raised *before* the gate check and dropped after the
    /// send, so a drain can never resolve in the window between a
    /// submitter passing the gate and its command landing — drain
    /// resolution requires this to be zero (strict quiescence) while
    /// the gated-then-sent submission is still admitted (zero loss).
    pending_submits: AtomicU64,
    snapshot: Mutex<ServiceSnapshot>,
}

/// A running inference service: one engine-loop thread owning the
/// scheduler + engine, fed through an MPSC control channel. Cheap to
/// share behind an `Arc`; dropped, it shuts the loop down and joins it.
pub struct Service {
    control: Sender<Command>,
    next_id: AtomicU64,
    /// Request-id namespace step (see [`ServiceBuilder::request_ids`]).
    id_stride: u64,
    shared: Arc<Shared>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl Service {
    pub fn builder(model: ModelSpec, hardware: HardwareSpec)
                   -> ServiceBuilder {
        ServiceBuilder::new(model, hardware)
    }

    /// Low-level constructor over an explicit scheduler (used by the
    /// builder and by [`crate::server::serve`]). The engine is built on
    /// the service thread because PJRT handles are not `Send`.
    pub fn with_scheduler<F>(engine_builder: F, sched: Scheduler)
                             -> Result<Service>
    where
        F: FnOnce() -> Result<Box<dyn Engine>> + Send + 'static,
    {
        Self::spawn(Box::new(engine_builder), sched,
                    &ReplicaProfile::baseline(), false, 1, 1)
    }

    fn spawn(engine_builder: EngineBuilderFn, sched: Scheduler,
             profile: &ReplicaProfile, paused: bool, id_start: u64,
             id_stride: u64) -> Result<Service> {
        let (control, commands) = std::sync::mpsc::channel();
        // The profile identity is static for the service's lifetime;
        // `publish` never touches these fields, so seeding the initial
        // snapshot is enough.
        let shared = Arc::new(Shared {
            shutdown: AtomicBool::new(false),
            paused: AtomicBool::new(paused),
            draining: AtomicBool::new(false),
            pending_submits: AtomicU64::new(0),
            snapshot: Mutex::new(ServiceSnapshot {
                profile: profile.name.clone(),
                decode_speed: profile.decode_speed,
                cost_unit: profile.cost_unit,
                ..ServiceSnapshot::default()
            }),
        });
        let worker = {
            let shared = shared.clone();
            let mut sched = sched;
            std::thread::Builder::new()
                .name("dynabatch-service".into())
                .spawn(move || {
                    let engine = match engine_builder() {
                        Ok(e) => e,
                        Err(e) => {
                            crate::log_error!("service",
                                              "engine init failed: {e}");
                            shared.shutdown.store(true, Ordering::SeqCst);
                            fail_pending(&commands,
                                         &format!("engine init failed: {e}"));
                            return;
                        }
                    };
                    engine_loop(engine, &mut sched, &commands, &shared);
                })?
        };
        Ok(Service {
            control,
            next_id: AtomicU64::new(id_start),
            id_stride,
            shared,
            worker: Some(worker),
        })
    }

    /// Submit a typed request; returns a handle streaming its events.
    /// Fails with a downcastable [`SubmitError`] when the service is
    /// draining or shut down.
    pub fn submit(&self, req: GenRequest) -> Result<SubmissionHandle> {
        req.validate()?;
        if self.is_shutdown() {
            return Err(anyhow::Error::new(SubmitError::ShutDown));
        }
        // Raise the pending counter BEFORE the draining check: a drain
        // that flips the flag right after we pass the gate observes the
        // counter and waits for this submission to land in the channel,
        // so drain-resolved strictly implies nothing in flight — while
        // the gated submission is still admitted, never failed.
        self.shared.pending_submits.fetch_add(1, Ordering::SeqCst);
        if self.is_draining() {
            self.shared.pending_submits.fetch_sub(1, Ordering::SeqCst);
            return Err(anyhow::Error::new(SubmitError::Draining));
        }
        let id = self.next_id.fetch_add(self.id_stride, Ordering::Relaxed);
        let request = Request::with_tokens(
            id,
            req.prompt_tokens,
            req.max_new_tokens,
            0.0, // stamped with the loop clock at acceptance
        )
        .with_class(req.class)
        .with_sampling(req.sampling)
        // Relative until the loop stamps arrival (see engine_loop).
        .with_deadline(req.deadline);
        let (events_tx, events_rx) = std::sync::mpsc::channel();
        let sent = self
            .control
            .send(Command::Submit { request, events: events_tx });
        self.shared.pending_submits.fetch_sub(1, Ordering::SeqCst);
        // A closed channel means the worker is dead — surface the same
        // typed error as an explicit shutdown so routers can fall
        // through to the next replica instead of failing the request.
        sent.map_err(|_| anyhow::Error::new(SubmitError::ShutDown))?;
        Ok(SubmissionHandle {
            id,
            events: events_rx,
            control: self.control.clone(),
            terminal: false,
        })
    }

    /// Request cancellation of any in-flight id (asynchronous; unknown or
    /// already-finished ids are ignored). Returns false only when the
    /// service worker is gone.
    pub fn cancel(&self, id: RequestId) -> bool {
        self.control.send(Command::Cancel(id)).is_ok()
    }

    pub fn snapshot(&self) -> ServiceSnapshot {
        self.shared.snapshot.lock().unwrap().clone()
    }

    /// Hot-swap the batching controller on the live scheduler: telemetry,
    /// queues, KV accounting and in-flight requests all carry over, and
    /// the next scheduler step re-decides under the new controller.
    /// Returns the new controller's label. Blocks briefly (one loop
    /// iteration) for the swap to be applied.
    pub fn reconfigure(&self, kind: PolicyKind) -> Result<String> {
        let (ack, rx) = std::sync::mpsc::channel();
        self.control
            .send(Command::SetPolicy { kind, ack })
            .map_err(|_| anyhow!("service worker is gone"))?;
        match rx.recv_timeout(Duration::from_secs(10)) {
            Ok(r) => r,
            Err(_) => bail!("service worker did not apply the policy"),
        }
    }

    /// Stop admitting new work and block until every in-flight request
    /// has reached its terminal event. Once draining starts, `submit`
    /// fails with [`SubmitError::Draining`]; cancels are still honored
    /// (and count as terminal). Idempotent — concurrent callers all
    /// resolve. Note: a paused service must be [`Service::resume`]d for
    /// in-flight work (and therefore the drain) to make progress.
    pub fn drain(&self) -> Result<()> {
        self.shared.draining.store(true, Ordering::SeqCst);
        let (done, rx) = std::sync::mpsc::channel();
        self.control
            .send(Command::Drain { done })
            .map_err(|_| anyhow!("service worker is gone"))?;
        rx.recv()
            .map_err(|_| anyhow!("service shut down before drain resolved"))
    }

    /// Flip the draining flag without waiting for in-flight work —
    /// `submit` starts failing with [`SubmitError::Draining`] right
    /// away. [`Service::drain`] does this and then blocks; a
    /// [`replica::ReplicaSet`] uses `begin_drain` to stop admissions on
    /// every replica before waiting them out one by one.
    pub fn begin_drain(&self) {
        self.shared.draining.store(true, Ordering::SeqCst);
    }

    /// Rejoin after a drain: clear the draining flag so `submit` accepts
    /// work again. The scheduler, telemetry and controller all carried
    /// over (a drained service is quiesced, not torn down), so
    /// drain → [`Service::reconfigure`] → reopen is a full replica
    /// rotation. Call only once a pending [`Service::drain`] has
    /// resolved — reopening under a still-blocked drain lets new work
    /// postpone it indefinitely.
    pub fn reopen(&self) {
        self.shared.draining.store(false, Ordering::SeqCst);
    }

    pub fn is_draining(&self) -> bool {
        self.shared.draining.load(Ordering::SeqCst)
    }

    /// Pause the stepping loop (submissions/cancels still processed).
    pub fn pause(&self) {
        self.shared.paused.store(true, Ordering::SeqCst);
    }

    pub fn resume(&self) {
        self.shared.paused.store(false, Ordering::SeqCst);
    }

    pub fn is_shutdown(&self) -> bool {
        self.shared.shutdown.load(Ordering::SeqCst)
    }

    /// Stop the engine loop; any open streams end with an error event.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        let _ = self.control.send(Command::Shutdown);
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.shutdown();
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

/// A submitted request: stream its [`GenEvent`]s, or [`cancel`] it.
///
/// [`cancel`]: SubmissionHandle::cancel
pub struct SubmissionHandle {
    id: RequestId,
    events: Receiver<GenEvent>,
    control: Sender<Command>,
    terminal: bool,
}

impl SubmissionHandle {
    pub fn id(&self) -> RequestId {
        self.id
    }

    /// Ask the service to cancel this request. Asynchronous: unless the
    /// request already finished, the stream ends with
    /// [`GenEvent::Cancelled`] and its KV blocks are freed.
    pub fn cancel(&self) {
        let _ = self.control.send(Command::Cancel(self.id));
    }

    /// Next event, blocking. `None` once the stream is over (terminal
    /// event already delivered, or the service died).
    pub fn next_event(&mut self) -> Option<GenEvent> {
        if self.terminal {
            return None;
        }
        match self.events.recv() {
            Ok(ev) => {
                self.terminal = ev.is_terminal();
                Some(ev)
            }
            Err(_) => {
                self.terminal = true;
                None
            }
        }
    }

    /// Nonblocking poll: `Some(ev)` if an event is ready, `None`
    /// otherwise. Never blocks — the event-loop server polls every open
    /// stream each lap with this. After the terminal event (check
    /// [`is_finished`](Self::is_finished)) it always returns `None`.
    pub fn try_next_event(&mut self) -> Option<GenEvent> {
        if self.terminal {
            return None;
        }
        match self.events.try_recv() {
            Ok(ev) => {
                self.terminal = ev.is_terminal();
                Some(ev)
            }
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => {
                self.terminal = true;
                None
            }
        }
    }

    /// `true` once the terminal event has been delivered (or the service
    /// died). A finished handle yields no further events.
    pub fn is_finished(&self) -> bool {
        self.terminal
    }

    /// Like [`next_event`](Self::next_event) but gives up after
    /// `timeout` (returning `None` without ending the stream).
    pub fn next_event_timeout(&mut self, timeout: Duration)
                              -> Option<GenEvent> {
        if self.terminal {
            return None;
        }
        match self.events.recv_timeout(timeout) {
            Ok(ev) => {
                self.terminal = ev.is_terminal();
                Some(ev)
            }
            Err(RecvTimeoutError::Timeout) => None,
            Err(RecvTimeoutError::Disconnected) => {
                self.terminal = true;
                None
            }
        }
    }

    /// Block until the stream ends, collecting tokens. `Err` on error,
    /// cancellation, or service death.
    pub fn wait(mut self) -> Result<Completion> {
        let mut tokens = Vec::new();
        while let Some(ev) = self.next_event() {
            match ev {
                GenEvent::Accepted { .. } => {}
                GenEvent::Token { token, .. } => tokens.push(token),
                GenEvent::Done { id, text, n_tokens, ttft, e2e } => {
                    return Ok(Completion {
                        id,
                        text,
                        tokens,
                        n_tokens,
                        ttft,
                        e2e,
                    });
                }
                GenEvent::Error { id, message } => {
                    bail!("request {id}: {message}");
                }
                GenEvent::Cancelled { id } => {
                    bail!("request {id} was cancelled");
                }
            }
        }
        bail!("service terminated before request {} finished", self.id)
    }
}

/// Fail queued submissions when the engine never came up. Accepted is
/// sent before the terminal error so blocking clients waiting for the
/// stream head do not hang.
fn fail_pending(commands: &Receiver<Command>, message: &str) {
    while let Ok(cmd) = commands.recv_timeout(Duration::from_millis(50)) {
        if let Command::Submit { request, events } = cmd {
            let _ = events.send(GenEvent::Accepted {
                id: request.id,
                class: request.class,
            });
            let _ = events.send(GenEvent::Error {
                id: request.id,
                message: message.to_string(),
            });
        }
    }
}

/// Resolve drain waiters once nothing is in flight: no scheduler work,
/// every stream has received its terminal event, and no submitter sits
/// between the draining gate and the control channel.
///
/// Resolution is two-phase: the quiescent condition (including
/// `no_pending_submits`, read at the top of the iteration, before the
/// channel drain) must hold on two consecutive iterations — `armed`
/// carries the first observation. This closes both gate races: a
/// submitter that passed the gate before the drain flag flipped has,
/// by the second iteration's top, either landed in the channel (the
/// intermediate channel drain processes it — its watcher, or its
/// terminal completion, is visible here) or still holds the pending
/// counter, failing the second check. (Waiters registered on an idle
/// service therefore resolve after two iterations.)
fn resolve_drains(no_pending_submits: bool, armed: &mut bool,
                  waiters: &mut Vec<Sender<()>>, sched: &Scheduler,
                  watchers: &HashMap<RequestId, Sender<GenEvent>>) {
    let quiet = no_pending_submits
        && !waiters.is_empty()
        && !sched.has_work()
        && watchers.is_empty();
    if quiet && *armed {
        for w in waiters.drain(..) {
            let _ = w.send(());
        }
        *armed = false;
    } else {
        *armed = quiet;
    }
}

/// Cached per-class decode-latency percentiles for the published
/// snapshot: `SlidingWindow::percentile` clones and sorts the window,
/// so the loop recomputes only when a decode step actually landed
/// (`decode_steps` moved) instead of on every iteration — idle and
/// prefill-only iterations publish the cached values.
#[derive(Default)]
struct ClassLatCache {
    decode_steps: u64,
    ttft_samples: u64,
    fresh: bool,
    p50: [f64; PriorityClass::COUNT],
    p95: [f64; PriorityClass::COUNT],
    ttft_p95: [f64; PriorityClass::COUNT],
}

impl ClassLatCache {
    fn refresh(&mut self, sched: &Scheduler) {
        if self.fresh
            && sched.stats.decode_steps == self.decode_steps
            && sched.telemetry.ttft_samples() == self.ttft_samples
        {
            return;
        }
        self.decode_steps = sched.stats.decode_steps;
        self.ttft_samples = sched.telemetry.ttft_samples();
        self.fresh = true;
        self.p50 = std::array::from_fn(|rank| {
            sched.telemetry.decode_latency_class_p(rank, 50.0)
        });
        self.p95 = std::array::from_fn(|rank| {
            sched.telemetry.decode_latency_class_p(rank, 95.0)
        });
        self.ttft_p95 = std::array::from_fn(|rank| {
            sched.telemetry.ttft_class_p(rank, 95.0)
        });
    }
}

/// `label` is the cached controller label — `controller_label()`
/// allocates across the combinator tree, so the loop re-derives it only
/// on `SetPolicy` instead of every iteration.
fn publish(shared: &Shared, sched: &Scheduler, label: &str,
           lat_cache: &mut ClassLatCache) {
    let mut snap = shared.snapshot.lock().unwrap();
    let by_class = sched.waiting_by_class();
    snap.running = sched.running_len() as u32;
    snap.waiting = by_class.iter().sum();
    snap.waiting_by_class = by_class;
    snap.resuming = sched.resume_len() as u32;
    snap.kv_used_tokens = sched.kv.used_tokens();
    snap.kv_free_blocks = sched.kv.free_blocks();
    snap.kv_total_blocks = sched.kv.total_blocks();
    snap.kv_shared_tokens = sched.kv.shared_tokens();
    snap.prefix_hit_rate = sched.kv.prefix_hit_rate();
    snap.prefill_padded_tokens = sched.telemetry.prefill_padded_tokens();
    snap.padding_waste = sched.telemetry.padding_waste();
    snap.b_t = sched.current_bt();
    if snap.controller != label {
        snap.controller = label.to_string();
    }
    snap.steps = sched.stats.steps;
    snap.finished = sched.stats.finished;
    snap.rejected = sched.stats.rejected;
    snap.shed = sched.stats.shed;
    snap.cancelled = sched.stats.cancelled;
    snap.reconfigs = sched.stats.reconfigs;
    snap.draining = shared.draining.load(Ordering::SeqCst);
    lat_cache.refresh(sched);
    snap.class_lat_p50 = lat_cache.p50;
    snap.class_lat_p95 = lat_cache.p95;
    snap.class_ttft_p95 = lat_cache.ttft_p95;
}

/// The serving loop: drain control commands, step the scheduler, stream
/// tokens, emit terminal events from the scheduler's finish reasons,
/// resolve drain waiters, and publish a snapshot — every iteration.
fn engine_loop(mut engine: Box<dyn Engine>, sched: &mut Scheduler,
               commands: &Receiver<Command>, shared: &Shared) {
    let clock = std::time::Instant::now();
    // Hot-path maps: looked up per emitted token, so hashed not ordered.
    let mut watchers: HashMap<RequestId, Sender<GenEvent>> = HashMap::new();
    let mut texts: HashMap<RequestId, Vec<i32>> = HashMap::new();
    let mut drain_waiters: Vec<Sender<()>> = Vec::new();
    // First-of-two quiescence observation for drain resolution (see
    // resolve_drains).
    let mut drain_armed = false;
    let mut label = sched.controller_label();
    let mut lat_cache = ClassLatCache::default();
    while !shared.shutdown.load(Ordering::SeqCst) {
        let now = clock.elapsed().as_secs_f64();
        // Read BEFORE draining the channel (see resolve_drains): zero
        // here + an empty channel below = no submission anywhere
        // between the draining gate and the scheduler.
        let no_pending_submits =
            shared.pending_submits.load(Ordering::SeqCst) == 0;
        // ---- 1. drain control commands ----
        loop {
            match commands.try_recv() {
                Ok(Command::Submit { mut request, events }) => {
                    // The draining gate lives in Service::submit (before
                    // the send), so anything already in the channel was
                    // accepted pre-drain: admit it and let the drain wait
                    // for it. The drain set may grow by this in-channel
                    // handful, never by new submissions — accepted work
                    // is never failed by a drain (the replica-rotation
                    // zero-loss guarantee builds on this).
                    request.arrived_at = now;
                    // Deadline arrives relative; make it absolute in the
                    // loop's clock domain.
                    request.deadline = request.deadline.map(|d| now + d);
                    let _ = events.send(GenEvent::Accepted {
                        id: request.id,
                        class: request.class,
                    });
                    watchers.insert(request.id, events);
                    texts.insert(request.id, Vec::new());
                    sched.submit(request);
                }
                Ok(Command::Cancel(id)) => {
                    if sched.cancel(engine.as_mut(), id, now) {
                        texts.remove(&id);
                        if let Some(tx) = watchers.remove(&id) {
                            let _ = tx.send(GenEvent::Cancelled { id });
                        }
                    }
                }
                Ok(Command::SetPolicy { kind, ack }) => {
                    let r = sched
                        .reconfigure(kind)
                        .map(|()| sched.controller_label());
                    if let Ok(l) = &r {
                        label = l.clone();
                    }
                    let _ = ack.send(r);
                }
                Ok(Command::Drain { done }) => {
                    // Service::drain set the flag before sending; set it
                    // again for callers driving the channel directly.
                    shared.draining.store(true, Ordering::SeqCst);
                    drain_waiters.push(done);
                }
                Ok(Command::Shutdown) => {
                    shared.shutdown.store(true, Ordering::SeqCst);
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    // Every Service handle dropped — nothing can submit
                    // or cancel anymore; drain and stop.
                    shared.shutdown.store(true, Ordering::SeqCst);
                }
            }
            if shared.shutdown.load(Ordering::SeqCst) {
                break;
            }
        }
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }

        // ---- 2. paused: keep the snapshot fresh, skip stepping ----
        if shared.paused.load(Ordering::SeqCst) {
            resolve_drains(no_pending_submits, &mut drain_armed,
                           &mut drain_waiters, sched, &watchers);
            publish(shared, sched, &label, &mut lat_cache);
            std::thread::sleep(Duration::from_millis(1));
            continue;
        }

        // ---- 3. one scheduler iteration ----
        if sched.has_work() {
            let now = clock.elapsed().as_secs_f64();
            match sched.step(engine.as_mut(), now) {
                Ok(Some(_elapsed)) => {
                    for (id, tok) in &sched.last_report().tokens {
                        if let Some(tx) = watchers.get(id) {
                            if let Some(buf) = texts.get_mut(id) {
                                buf.push(*tok);
                            }
                            let _ = tx.send(GenEvent::Token {
                                id: *id,
                                token: *tok,
                                text: tokenizer::decode(&[*tok]),
                            });
                        }
                    }
                }
                Ok(None) => std::thread::sleep(Duration::from_millis(1)),
                Err(e) => {
                    let message = format!("engine step failed: {e}");
                    crate::log_error!("service", "{message}");
                    for (id, tx) in std::mem::take(&mut watchers) {
                        let _ = tx.send(GenEvent::Error {
                            id,
                            message: message.clone(),
                        });
                    }
                    shared.shutdown.store(true, Ordering::SeqCst);
                    break;
                }
            }
        } else {
            std::thread::sleep(Duration::from_millis(2));
        }

        // ---- 4. terminal events from finish reasons ----
        for r in sched.take_finished() {
            let toks = texts.remove(&r.id).unwrap_or_default();
            let Some(tx) = watchers.remove(&r.id) else {
                continue; // cancelled (event already sent) or untracked
            };
            let ev = match r.finish {
                Some(FinishReason::Completed) | None => GenEvent::Done {
                    id: r.id,
                    text: tokenizer::decode(&toks),
                    n_tokens: r.generated,
                    ttft: r.ttft().unwrap_or(0.0),
                    e2e: r.e2e_latency().unwrap_or(0.0),
                },
                Some(FinishReason::Rejected) => GenEvent::Error {
                    id: r.id,
                    message: "rejected: prompt + generation budget exceeds \
                              the engine's maximum sequence length"
                        .into(),
                },
                Some(FinishReason::DeadlineExceeded) => GenEvent::Error {
                    id: r.id,
                    message: "deadline exceeded before the first token"
                        .into(),
                },
                Some(FinishReason::Cancelled) => GenEvent::Cancelled {
                    id: r.id,
                },
                Some(FinishReason::Failed) => GenEvent::Error {
                    id: r.id,
                    message: "replica failed mid-stream".into(),
                },
            };
            let _ = tx.send(ev);
        }
        resolve_drains(no_pending_submits, &mut drain_armed,
                       &mut drain_waiters, sched, &watchers);
        publish(shared, sched, &label, &mut lat_cache);
    }
    // Shutdown: fail submissions still queued in the control channel,
    // then end any open stream, so callers never hang.
    while let Ok(cmd) = commands.try_recv() {
        if let Command::Submit { request, events } = cmd {
            let _ = events.send(GenEvent::Accepted {
                id: request.id,
                class: request.class,
            });
            let _ = events.send(GenEvent::Error {
                id: request.id,
                message: "service shut down".into(),
            });
        }
    }
    for (id, tx) in watchers {
        let _ = tx.send(GenEvent::Error {
            id,
            message: "service shut down".into(),
        });
    }
    publish(shared, sched, &label, &mut lat_cache);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::{cpu_host, tiny_real};

    fn sim_service() -> Service {
        ServiceBuilder::new(tiny_real(), cpu_host())
            .policy(PolicyKind::Combined)
            .d_sla(0.05)
            .eta_tokens(100_000)
            .build()
            .unwrap()
    }

    /// Poll until the published snapshot satisfies `ok` (the loop
    /// publishes once per iteration) or a 5 s deadline trips.
    fn snapshot_when(service: &Service,
                     ok: impl Fn(&ServiceSnapshot) -> bool)
                     -> ServiceSnapshot {
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        loop {
            let s = service.snapshot();
            if ok(&s) {
                return s;
            }
            assert!(std::time::Instant::now() < deadline,
                    "snapshot never converged: {s:?}");
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    #[test]
    fn submit_stream_done() {
        let service = sim_service();
        let handle = service
            .submit(GenRequest::from_text("hello service", 6))
            .unwrap();
        let c = handle.wait().unwrap();
        assert_eq!(c.n_tokens, 6);
        assert_eq!(c.tokens.len(), 6);
        assert!(c.e2e >= c.ttft);
        service.shutdown();
    }

    #[test]
    fn invalid_requests_rejected_at_submit() {
        let service = sim_service();
        assert!(service.submit(GenRequest::new(vec![1], 0)).is_err());
        let mut bad = GenRequest::new(vec![1], 4);
        bad.sampling.temperature = f64::NAN;
        assert!(service.submit(bad).is_err());
    }

    #[test]
    fn snapshot_reflects_drained_state() {
        let service = sim_service();
        let h = service.submit(GenRequest::from_text("snap", 4)).unwrap();
        h.wait().unwrap();
        // The loop publishes after the finishing iteration.
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        loop {
            let s = service.snapshot();
            if s.finished >= 1 && s.kv_used_tokens == 0 {
                assert_eq!(s.kv_free_blocks, s.kv_total_blocks);
                assert_eq!(s.running, 0);
                break;
            }
            assert!(std::time::Instant::now() < deadline, "snapshot stale");
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    #[test]
    fn oversized_request_streams_error() {
        // tiny_real's max_model_len is far below this budget.
        let service = sim_service();
        let handle = service
            .submit(GenRequest::new(vec![0; 10], 1_000_000))
            .unwrap();
        let err = handle.wait().unwrap_err();
        assert!(err.to_string().contains("maximum sequence length"),
                "{err}");
    }

    #[test]
    fn reconfigure_swaps_controller_label() {
        let service = sim_service();
        let snap = snapshot_when(&service, |s| !s.controller.is_empty());
        assert_eq!(snap.controller, "combined(min(alg1,alg2))");
        let label = service
            .reconfigure(PolicyKind::StaticFixed { batch: 4 })
            .unwrap();
        assert_eq!(label, "static-fixed:4");
        let snap =
            snapshot_when(&service, |s| s.controller == "static-fixed:4");
        assert_eq!(snap.reconfigs, 1);
        // Invalid policies are rejected without killing the loop.
        assert!(service
            .reconfigure(PolicyKind::StaticFixed { batch: 0 })
            .is_err());
        let c = service.submit(GenRequest::from_text("still up", 3)).unwrap();
        assert_eq!(c.wait().unwrap().n_tokens, 3);
        service.shutdown();
    }

    #[test]
    fn drain_on_idle_service_resolves_and_rejects_submits() {
        let service = sim_service();
        service.drain().unwrap();
        assert!(service.is_draining());
        let err = service
            .submit(GenRequest::from_text("too late", 2))
            .unwrap_err();
        assert_eq!(err.downcast_ref::<SubmitError>(),
                   Some(&SubmitError::Draining));
        assert!(snapshot_when(&service, |s| s.draining).draining);
        service.shutdown();
    }

    #[test]
    fn reopen_after_drain_serves_again() {
        let service = sim_service();
        let h = service.submit(GenRequest::from_text("before", 3)).unwrap();
        assert_eq!(h.wait().unwrap().n_tokens, 3);
        service.drain().unwrap();
        assert!(service.is_draining());
        assert!(service.submit(GenRequest::from_text("no", 2)).is_err());
        // Rejoin: the same scheduler/controller serve again.
        service.reopen();
        assert!(!service.is_draining());
        let h = service.submit(GenRequest::from_text("after", 4)).unwrap();
        assert_eq!(h.wait().unwrap().n_tokens, 4);
        service.shutdown();
    }

    #[test]
    fn request_id_namespace_start_and_stride() {
        let service = ServiceBuilder::new(tiny_real(), cpu_host())
            .eta_tokens(100_000)
            .request_ids(3, 4) // replica 2 of a 4-wide set
            .paused(true)
            .build()
            .unwrap();
        let ids: Vec<u64> = (0..3)
            .map(|_| {
                service
                    .submit(GenRequest::from_text("ns", 1))
                    .unwrap()
                    .id()
            })
            .collect();
        assert_eq!(ids, vec![3, 7, 11]);
        service.shutdown();
    }

    #[test]
    fn profile_scales_eta_and_tags_snapshot() {
        let profile = ReplicaProfile {
            name: "half-kv".into(),
            kv_scale: 0.5,
            decode_speed: 1.2,
            prefill_speed: 1.1,
            cost_unit: 1.3,
        };
        let service = ServiceBuilder::new(tiny_real(), cpu_host())
            .eta_tokens(100_000)
            .profile(profile)
            .paused(true)
            .build()
            .unwrap();
        let snap = snapshot_when(&service, |s| s.kv_total_blocks > 0);
        assert_eq!(snap.profile, "half-kv");
        assert_eq!(snap.decode_speed, 1.2);
        assert_eq!(snap.cost_unit, 1.3);
        // η was halved: 50_000 tokens of KV blocks, not 100_000.
        let unscaled = sim_service();
        let base =
            snapshot_when(&unscaled, |s| s.kv_total_blocks > 0);
        assert_eq!(base.profile, "baseline");
        assert_eq!(base.cost_unit, 1.0);
        assert_eq!(snap.kv_total_blocks * 2, base.kv_total_blocks);
        service.shutdown();
        unscaled.shutdown();
    }

    #[test]
    fn snapshot_surfaces_live_ttft_p95() {
        let service = sim_service();
        let h = service
            .submit(GenRequest::from_text("ttft probe", 4)
                .with_class(PriorityClass::Interactive))
            .unwrap();
        h.wait().unwrap();
        let rank = PriorityClass::Interactive.rank();
        let snap = snapshot_when(&service, |s| {
            s.class_ttft_p95[rank] > 0.0
        });
        assert!(snap.class_ttft_p95[rank] > 0.0);
        service.shutdown();
    }

    #[test]
    fn shutdown_fails_open_streams() {
        let service = ServiceBuilder::new(tiny_real(), cpu_host())
            .eta_tokens(100_000)
            .paused(true)
            .build()
            .unwrap();
        let handle =
            service.submit(GenRequest::from_text("never runs", 4)).unwrap();
        service.shutdown();
        let err = handle.wait().unwrap_err();
        assert!(err.to_string().contains("shut down"), "{err}");
    }
}
