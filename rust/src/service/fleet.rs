//! Fleet layer — heterogeneous replica profiles and an SLA-driven
//! autoscaler over the replica tier.
//!
//! The replica tier ([`super::replica`]) assumes someone decided how
//! many replicas to run; this module is that someone. It adds three
//! pieces on top of a [`ReplicaSet`]:
//!
//! * **Profiles** — each replica is deployed under a
//!   [`ReplicaProfile`](crate::config::ReplicaProfile) (KV pool scale,
//!   decode/prefill speed, cost per replica-second) instead of being a
//!   clone of one spec; the profile shows up in every snapshot and
//!   load view, so routing and scaling can tell replicas apart.
//! * **[`FleetController`]** — the fleet-level analogue of the batch
//!   controller: it watches a [`FleetObservation`] (backlog, KV
//!   pressure, live per-class TTFT p95) and emits a
//!   [`FleetDirective`]. The shipped [`SlaAutoscaler`] uses hysteresis
//!   bands with dwell counters and a cooldown so a load step produces
//!   one action, not a flap.
//! * **[`Fleet`]** — the executor: a fixed provisioned pool of
//!   replicas where scale-down parks a replica via the zero-loss
//!   `begin_drain` primitive (in-flight work finishes; the router
//!   skips it immediately) and scale-up reopens a parked replica
//!   matching the requested profile. No replica is ever torn down, so
//!   scaling is loss-free by construction and spawn latency is one
//!   `reopen`.
//!
//! The virtual-time twin is [`crate::driver::run_fleet_sim`], which
//! replays the same controller against simulated replicas and prices
//! the run in cost units (replica-seconds × profile cost).

use super::replica::{ReplicaLoad, ReplicaSet, RoutePolicy};
use crate::config::{FleetConfig, FleetPolicyKind, ReplicaProfile};
use crate::request::PriorityClass;
use anyhow::{bail, Result};
use std::sync::{Arc, Mutex};

/// What a [`FleetController`] sees each decision tick: the per-replica
/// load views (draining replicas included — they are the parked pool)
/// plus the fleet-level per-class TTFT p95 (worst live replica, the
/// conservative SLA read).
#[derive(Debug, Clone)]
pub struct FleetObservation {
    /// Decision clock (wall time on the live path, virtual time in the
    /// driver).
    pub now: f64,
    /// Index-aligned with the fleet's replicas.
    pub loads: Vec<ReplicaLoad>,
    /// Live per-class TTFT p95 (seconds, worst live replica; 0.0 until
    /// a class has seen first tokens), indexed by
    /// [`PriorityClass::rank`].
    pub class_ttft_p95: [f64; PriorityClass::COUNT],
}

impl FleetObservation {
    /// Replicas currently serving new traffic: not draining/parked and
    /// health-routable. A `Down` replica is capacity the fleet has
    /// *lost*, not capacity it holds — excluding it here is what makes
    /// the autoscaler spawn to cover an unplanned failure exactly like
    /// a load step.
    pub fn live(&self) -> usize {
        self.loads.iter().filter(|l| l.routable()).count()
    }

    /// Mean backlog per live replica — the primary scale signal (a
    /// fleet-size-invariant load measure).
    pub fn backlog_per_live(&self) -> f64 {
        let live = self.live();
        if live == 0 {
            return 0.0;
        }
        let backlog: u64 = self
            .loads
            .iter()
            .filter(|l| l.routable())
            .map(|l| l.backlog())
            .sum();
        backlog as f64 / live as f64
    }

    /// Fraction of the live fleet's KV blocks in use, in `[0, 1]`.
    pub fn kv_pressure(&self) -> f64 {
        let (mut free, mut total) = (0usize, 0usize);
        for l in self.loads.iter().filter(|l| l.routable()) {
            free += l.kv_free_blocks;
            total += l.kv_total_blocks;
        }
        if total == 0 {
            return 0.0;
        }
        1.0 - free as f64 / total as f64
    }
}

/// What a [`FleetController`] wants done. The executor ([`Fleet`] live,
/// [`crate::driver::run_fleet_sim`] in virtual time) carries it out via
/// the zero-loss drain/reopen primitives.
#[derive(Debug, Clone, PartialEq)]
pub enum FleetDirective {
    /// Nothing this tick.
    Hold,
    /// Bring up one more replica of `profile` (live: reopen a parked
    /// replica matching it; sim: add a fresh simulated replica).
    Spawn { profile: ReplicaProfile },
    /// Park replica `replica`: stop routing to it now, let in-flight
    /// work finish (zero-loss scale-down).
    Retire { replica: usize },
    /// Switch the routing policy (e.g. drop to plain least-loaded when
    /// the fleet became homogeneous). The sim driver applies it to its
    /// router; the live [`Fleet`] records it for the embedding layer,
    /// whose router owns the policy.
    Repin { route: RoutePolicy },
}

impl FleetDirective {
    /// Compact render for directive logs and the wire.
    pub fn label(&self) -> String {
        match self {
            FleetDirective::Hold => "hold".into(),
            FleetDirective::Spawn { profile } => {
                format!("spawn({})", profile.name)
            }
            FleetDirective::Retire { replica } => {
                format!("retire({replica})")
            }
            FleetDirective::Repin { route } => {
                format!("repin({})", route.label())
            }
        }
    }
}

/// Fleet-level analogue of the batch-controller trait: one decision per
/// tick over the aggregate observation. Implementations are stateful
/// (hysteresis needs memory) and run under the fleet's lock.
pub trait FleetController: Send {
    fn decide(&mut self, obs: &FleetObservation) -> FleetDirective;
    fn label(&self) -> String;
}

/// The shipped autoscaler: scale up when the fleet is overloaded
/// (backlog per live replica above the spawn band, KV pressure above
/// the spawn threshold, or a class's live TTFT p95 eating past
/// `spawn_sla_frac` of its target), scale down when it is comfortably
/// under every band. Hysteresis is three-fold — the up/down bands are
/// separated, a condition must hold `dwell_decisions` consecutive
/// ticks, and every action starts a cooldown — so a load step produces
/// exactly one action instead of a flap (asserted in this module's
/// tests).
///
/// Retirement prefers the most expensive live replica (highest profile
/// `cost_unit`, ties to the highest index), so burst capacity pays for
/// itself only while needed.
pub struct SlaAutoscaler {
    cfg: FleetConfig,
    /// What to spawn on scale-up.
    spawn_profile: ReplicaProfile,
    up_streak: u32,
    down_streak: u32,
    cooldown_until: f64,
}

impl SlaAutoscaler {
    pub fn new(cfg: FleetConfig, spawn_profile: ReplicaProfile)
               -> Result<Self> {
        cfg.validate()?;
        spawn_profile.validate()?;
        Ok(SlaAutoscaler {
            cfg,
            spawn_profile,
            up_streak: 0,
            down_streak: 0,
            cooldown_until: f64::NEG_INFINITY,
        })
    }

    pub fn config(&self) -> &FleetConfig {
        &self.cfg
    }

    /// Any spawn trigger tripped?
    fn overloaded(&self, obs: &FleetObservation) -> bool {
        if obs.backlog_per_live() > self.cfg.spawn_backlog {
            return true;
        }
        if obs.kv_pressure() > self.cfg.spawn_kv_pressure {
            return true;
        }
        self.cfg.ttft_targets.iter().enumerate().any(|(rank, t)| {
            t.is_some_and(|t| {
                obs.class_ttft_p95[rank] > self.cfg.spawn_sla_frac * t
            })
        })
    }

    /// Comfortably under *every* band (the retire side of the
    /// hysteresis gap)?
    fn underloaded(&self, obs: &FleetObservation) -> bool {
        obs.backlog_per_live() < self.cfg.retire_backlog
            && obs.kv_pressure() < self.cfg.spawn_kv_pressure
            && self.cfg.ttft_targets.iter().enumerate().all(|(rank, t)| {
                !t.is_some_and(|t| {
                    obs.class_ttft_p95[rank]
                        >= self.cfg.retire_sla_frac * t
                })
            })
    }

    /// The live replica to park: highest profile cost first, ties to
    /// the highest index (LIFO over equal-cost replicas). A `Down` or
    /// `Suspect` replica is never the pick — it already takes no
    /// traffic, so parking it would waste the scale-down action.
    fn retire_pick(obs: &FleetObservation) -> Option<usize> {
        (0..obs.loads.len())
            .filter(|&i| obs.loads[i].routable())
            .max_by(|&a, &b| {
                obs.loads[a]
                    .cost_unit
                    .total_cmp(&obs.loads[b].cost_unit)
                    .then(a.cmp(&b))
            })
    }
}

impl FleetController for SlaAutoscaler {
    fn decide(&mut self, obs: &FleetObservation) -> FleetDirective {
        if obs.now < self.cooldown_until {
            // Streaks do not accumulate through a cooldown — the fleet
            // is still absorbing the last action.
            self.up_streak = 0;
            self.down_streak = 0;
            return FleetDirective::Hold;
        }
        let live = obs.live();
        if self.overloaded(obs) {
            self.down_streak = 0;
            self.up_streak += 1;
            if self.up_streak >= self.cfg.dwell_decisions
                && live < self.cfg.max_replicas
            {
                self.up_streak = 0;
                self.cooldown_until = obs.now + self.cfg.cooldown;
                return FleetDirective::Spawn {
                    profile: self.spawn_profile.clone(),
                };
            }
        } else if self.underloaded(obs) {
            self.up_streak = 0;
            self.down_streak += 1;
            if self.down_streak >= self.cfg.dwell_decisions
                && live > self.cfg.min_replicas
            {
                if let Some(replica) = Self::retire_pick(obs) {
                    self.down_streak = 0;
                    self.cooldown_until = obs.now + self.cfg.cooldown;
                    return FleetDirective::Retire { replica };
                }
            }
        } else {
            // Inside the hysteresis gap: decay both streaks so only
            // consecutive evidence triggers an action.
            self.up_streak = 0;
            self.down_streak = 0;
        }
        FleetDirective::Hold
    }

    fn label(&self) -> String {
        FleetPolicyKind::Autoscale(self.cfg.clone()).label()
    }
}

/// Build the controller a [`FleetPolicyKind`] names. `spawn_profile` is
/// what an autoscaler brings up on scale-up (`Manual` needs none and
/// yields `None`).
pub fn build_fleet_controller(policy: &FleetPolicyKind,
                              spawn_profile: &ReplicaProfile)
                              -> Result<Option<Box<dyn FleetController>>> {
    match policy {
        FleetPolicyKind::Manual => Ok(None),
        FleetPolicyKind::Autoscale(cfg) => {
            let c = SlaAutoscaler::new(cfg.clone(), spawn_profile.clone())?;
            Ok(Some(Box::new(c)))
        }
    }
}

/// One rendered directive-log entry: when, what, and whether the
/// executor could carry it out.
#[derive(Debug, Clone)]
pub struct FleetLogEntry {
    pub at: f64,
    pub directive: String,
    /// False when the directive could not be executed (e.g. a spawn
    /// with no parked replica of the requested profile).
    pub applied: bool,
}

/// Point-in-time fleet view for operators (the v2 `fleet_stats` op).
#[derive(Debug, Clone)]
pub struct FleetStats {
    /// Total provisioned pool size (live + parked).
    pub n_replicas: usize,
    /// Replicas currently serving.
    pub live: usize,
    /// Per-replica profile names, index-aligned.
    pub profiles: Vec<String>,
    /// Per-replica parked flags (draining or shut down), index-aligned.
    pub parked: Vec<bool>,
    /// Per-replica health labels (`healthy`/`suspect`/`down`/
    /// `recovering`), index-aligned.
    pub health: Vec<String>,
    /// Fleet policy label (`manual` or the autoscale band spec).
    pub policy: String,
    /// Decision ticks taken so far.
    pub ticks: u64,
    /// The directive log (actions only — `hold` ticks are not logged).
    pub log: Vec<FleetLogEntry>,
}

struct FleetInner {
    policy: FleetPolicyKind,
    controller: Option<Box<dyn FleetController>>,
    ticks: u64,
    log: Vec<FleetLogEntry>,
}

/// The live fleet executor: a provisioned pool of profiled replicas
/// where the controller's spawn/retire directives map onto the
/// zero-loss `reopen`/`begin_drain` primitives. Drive it by calling
/// [`Fleet::tick`] on a timer (the server does) or manually via
/// [`Fleet::scale`].
///
/// ```
/// use dynabatch::config::presets::{cpu_host, profile_by_name,
///                                  tiny_real};
/// use dynabatch::config::FleetPolicyKind;
/// use dynabatch::service::{Fleet, ReplicaSet, RoutePolicy,
///                          ServiceBuilder};
/// use std::sync::Arc;
///
/// let profiles = vec![
///     profile_by_name("baseline").unwrap(),
///     profile_by_name("economy").unwrap(),
/// ];
/// let mk = {
///     let profiles = profiles.clone();
///     move |i: usize| {
///         ServiceBuilder::new(tiny_real(), cpu_host())
///             .eta_tokens(100_000)
///             .profile(profiles[i].clone())
///     }
/// };
/// let set = Arc::new(ReplicaSet::build(
///     2,
///     RoutePolicy::Capability { long_prompt: 512 },
///     mk,
/// )?);
/// let fleet =
///     Fleet::new(set.clone(), profiles, FleetPolicyKind::Manual)?;
/// fleet.scale(1)?; // parks the pricier baseline; economy serves
/// assert_eq!(fleet.stats().live, 1);
/// set.shutdown();
/// # Ok::<(), anyhow::Error>(())
/// ```
pub struct Fleet {
    set: Arc<ReplicaSet>,
    /// Index-aligned with the set's replicas; immutable after build
    /// (the pool is provisioned, not grown).
    profiles: Vec<ReplicaProfile>,
    inner: Mutex<FleetInner>,
}

impl Fleet {
    /// Wrap a built [`ReplicaSet`] whose replica `i` was deployed under
    /// `profiles[i]` (via [`super::ServiceBuilder::profile`]). All
    /// replicas start live; park the reserve with [`Fleet::scale`].
    pub fn new(set: Arc<ReplicaSet>, profiles: Vec<ReplicaProfile>,
               policy: FleetPolicyKind) -> Result<Fleet> {
        if profiles.len() != set.len() {
            bail!(
                "fleet needs one profile per replica ({} profiles, {} \
                 replicas)",
                profiles.len(),
                set.len()
            );
        }
        for p in &profiles {
            p.validate()?;
        }
        policy.validate()?;
        let controller =
            build_fleet_controller(&policy, &Self::spawn_choice(&profiles))?;
        Ok(Fleet {
            set,
            profiles,
            inner: Mutex::new(FleetInner {
                policy,
                controller,
                ticks: 0,
                log: Vec::new(),
            }),
        })
    }

    /// The profile an autoscaler spawns: the cheapest in the pool
    /// (burst capacity should cost as little as possible; capability
    /// routing keeps latency-bound work on the fast replicas).
    fn spawn_choice(profiles: &[ReplicaProfile]) -> ReplicaProfile {
        profiles
            .iter()
            .min_by(|a, b| a.cost_unit.total_cmp(&b.cost_unit))
            .cloned()
            .unwrap_or_else(ReplicaProfile::baseline)
    }

    pub fn set(&self) -> &Arc<ReplicaSet> {
        &self.set
    }

    pub fn profiles(&self) -> &[ReplicaProfile] {
        &self.profiles
    }

    /// Swap the fleet policy (controller state resets — bands and
    /// streaks start fresh). Returns the new policy's label.
    pub fn set_policy(&self, policy: FleetPolicyKind) -> Result<String> {
        policy.validate()?;
        let controller = build_fleet_controller(
            &policy,
            &Self::spawn_choice(&self.profiles),
        )?;
        let mut inner = self.inner.lock().unwrap();
        let label = policy.label();
        inner.policy = policy;
        inner.controller = controller;
        Ok(label)
    }

    pub fn policy_label(&self) -> String {
        self.inner.lock().unwrap().policy.label()
    }

    /// Seconds between decision ticks under the current policy (`None`
    /// for manual fleets) — what the server's ticker thread sleeps.
    pub fn decide_interval(&self) -> Option<f64> {
        match &self.inner.lock().unwrap().policy {
            FleetPolicyKind::Manual => None,
            FleetPolicyKind::Autoscale(c) => Some(c.decide_interval),
        }
    }

    /// Build the controller's view: the set's live load vector plus the
    /// worst-live-replica per-class TTFT p95.
    pub fn observation(&self, now: f64) -> FleetObservation {
        let loads = self.set.loads();
        let mut ttft = [0.0f64; PriorityClass::COUNT];
        for (snap, load) in
            self.set.snapshots().iter().zip(loads.iter())
        {
            // Skip non-routable replicas too: a crashed replica's last
            // published p95 is frozen at its worst — folding it in
            // would trigger spawns forever.
            if !load.routable() {
                continue;
            }
            for rank in 0..PriorityClass::COUNT {
                ttft[rank] = ttft[rank].max(snap.class_ttft_p95[rank]);
            }
        }
        FleetObservation { now, loads, class_ttft_p95: ttft }
    }

    /// One decision tick: observe, ask the controller, execute the
    /// directive, log it. Manual fleets hold. Returns the directive
    /// (executed or not — see [`FleetLogEntry::applied`]).
    pub fn tick(&self, now: f64) -> Result<FleetDirective> {
        let obs = self.observation(now);
        let mut inner = self.inner.lock().unwrap();
        inner.ticks += 1;
        let Some(controller) = inner.controller.as_mut() else {
            return Ok(FleetDirective::Hold);
        };
        let directive = controller.decide(&obs);
        if directive == FleetDirective::Hold {
            return Ok(directive);
        }
        let applied = self.execute(&directive, &obs);
        inner.log.push(FleetLogEntry {
            at: now,
            directive: directive.label(),
            applied,
        });
        Ok(directive)
    }

    /// Carry a directive out against the pool. Returns false when it
    /// could not be applied (nothing to reopen / retire target gone) —
    /// the fleet holds rather than errors, since the next tick gets a
    /// fresh observation.
    fn execute(&self, d: &FleetDirective, obs: &FleetObservation) -> bool {
        match d {
            FleetDirective::Hold => true,
            FleetDirective::Spawn { profile } => {
                // Prefer a parked replica of the requested profile;
                // any parked capacity (cheapest first) beats holding
                // while overloaded.
                match self
                    .parked_with_profile(obs, &profile.name)
                    .or_else(|| self.cheapest_parked(obs))
                {
                    Some(i) => {
                        self.set.replica(i).reopen();
                        true
                    }
                    None => false,
                }
            }
            FleetDirective::Retire { replica } => {
                if *replica < self.set.len()
                    && !obs.loads[*replica].draining
                {
                    self.set.replica(*replica).begin_drain();
                    true
                } else {
                    false
                }
            }
            // The live router's policy belongs to the ReplicaSet the
            // embedding layer built; record only.
            FleetDirective::Repin { .. } => false,
        }
    }

    /// A parked (draining, not shut down) replica deployed under the
    /// named profile, preferring the lowest index.
    fn parked_with_profile(&self, obs: &FleetObservation, name: &str)
                           -> Option<usize> {
        (0..self.set.len()).find(|&i| {
            obs.loads[i].draining
                && !self.set.replica(i).is_shutdown()
                && self.profiles[i].name == name
        })
    }

    /// The cheapest parked replica, ties to the lowest index.
    fn cheapest_parked(&self, obs: &FleetObservation) -> Option<usize> {
        (0..self.set.len())
            .filter(|&i| {
                obs.loads[i].draining
                    && !self.set.replica(i).is_shutdown()
            })
            .min_by(|&a, &b| {
                self.profiles[a]
                    .cost_unit
                    .total_cmp(&self.profiles[b].cost_unit)
                    .then(a.cmp(&b))
            })
    }

    /// Manual scaling: bring the live count to `target` by reopening
    /// parked replicas (cheapest profile first) or parking live ones
    /// (most expensive first — the same preference the autoscaler
    /// uses). Returns the live count after. Zero-loss: parking only
    /// stops admissions; in-flight work finishes.
    pub fn scale(&self, target: usize) -> Result<usize> {
        if target == 0 || target > self.set.len() {
            bail!(
                "scale target {target} out of range (pool has {} \
                 replicas; 0 is not a fleet)",
                self.set.len()
            );
        }
        let mut inner = self.inner.lock().unwrap();
        let loads = self.set.loads();
        let mut live: Vec<usize> =
            (0..loads.len()).filter(|&i| !loads[i].draining).collect();
        let mut parked: Vec<usize> = (0..loads.len())
            .filter(|&i| {
                loads[i].draining && !self.set.replica(i).is_shutdown()
            })
            .collect();
        // Reopen cheapest-first, park most-expensive-first.
        parked.sort_by(|&a, &b| {
            self.profiles[a]
                .cost_unit
                .total_cmp(&self.profiles[b].cost_unit)
                .then(a.cmp(&b))
        });
        live.sort_by(|&a, &b| {
            self.profiles[b]
                .cost_unit
                .total_cmp(&self.profiles[a].cost_unit)
                .then(b.cmp(&a))
        });
        while live.len() < target {
            let Some(i) = parked.first().copied() else {
                bail!(
                    "scale to {target}: only {} replicas available \
                     (rest shut down)",
                    live.len()
                );
            };
            parked.remove(0);
            self.set.replica(i).reopen();
            inner.log.push(FleetLogEntry {
                at: f64::NAN,
                directive: format!("scale:reopen({i})"),
                applied: true,
            });
            live.push(i);
        }
        while live.len() > target {
            let i = live.remove(0);
            self.set.replica(i).begin_drain();
            inner.log.push(FleetLogEntry {
                at: f64::NAN,
                directive: format!("scale:park({i})"),
                applied: true,
            });
        }
        Ok(live.len())
    }

    pub fn stats(&self) -> FleetStats {
        let loads = self.set.loads();
        let inner = self.inner.lock().unwrap();
        FleetStats {
            n_replicas: self.set.len(),
            live: loads.iter().filter(|l| l.routable()).count(),
            profiles: self
                .profiles
                .iter()
                .map(|p| p.name.clone())
                .collect(),
            parked: loads.iter().map(|l| l.draining).collect(),
            health: loads
                .iter()
                .map(|l| l.health.label().to_string())
                .collect(),
            policy: inner.policy.label(),
            ticks: inner.ticks,
            log: inner.log.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::{cpu_host, profile_by_name, tiny_real};
    use crate::service::{GenRequest, ServiceBuilder};

    /// Synthetic observation: `n` live replicas sharing `backlog`
    /// waiting requests (plus `parked` parked ones), KV half-used, no
    /// TTFT samples.
    fn obs(now: f64, n: usize, parked: usize, backlog: u32)
           -> FleetObservation {
        let mut loads = Vec::new();
        for i in 0..n {
            loads.push(ReplicaLoad {
                waiting: if i == 0 { backlog } else { 0 },
                kv_free_blocks: 50,
                kv_total_blocks: 100,
                ..ReplicaLoad::default()
            });
        }
        for _ in 0..parked {
            loads.push(ReplicaLoad {
                draining: true,
                kv_free_blocks: 100,
                kv_total_blocks: 100,
                ..ReplicaLoad::default()
            });
        }
        FleetObservation {
            now,
            loads,
            class_ttft_p95: [0.0; PriorityClass::COUNT],
        }
    }

    fn band_cfg() -> FleetConfig {
        FleetConfig {
            spawn_backlog: 10.0,
            retire_backlog: 2.0,
            dwell_decisions: 2,
            decide_interval: 0.25,
            cooldown: 1.0,
            min_replicas: 1,
            max_replicas: 3,
            ..FleetConfig::default()
        }
    }

    /// Satellite regression: a load step up then down produces exactly
    /// one spawn and one retire on the directive log — the hysteresis
    /// bands, dwell and cooldown must not flap. The synthetic fleet
    /// executes each directive (live count tracks the controller), so
    /// a sustained burst cannot be mistaken for N bursts.
    #[test]
    fn autoscaler_hysteresis_one_spawn_one_retire() {
        let mut c = SlaAutoscaler::new(
            band_cfg(),
            profile_by_name("economy").unwrap(),
        )
        .unwrap();
        let mut actions: Vec<FleetDirective> = Vec::new();
        let mut t = 0.0;
        let mut live = 1usize;
        let mut parked = 1usize;
        // Offered load per phase is the total backlog shared by the
        // live replicas: 16 → 16/1 over the spawn band (10) but
        // 16/2 = 8 inside the gap; 2 → 2/2 = 1 under the retire band
        // (2) but 2/1 = 2 back in the gap at the floor.
        let mut phase = |c: &mut SlaAutoscaler,
                         actions: &mut Vec<FleetDirective>,
                         t: &mut f64,
                         live: &mut usize,
                         parked: &mut usize,
                         ticks: usize,
                         backlog: u32| {
            for _ in 0..ticks {
                let d = c.decide(&obs(*t, *live, *parked, backlog));
                *t += 0.25;
                match &d {
                    FleetDirective::Hold => {}
                    FleetDirective::Spawn { .. } => {
                        *live += 1;
                        *parked -= 1;
                        actions.push(d);
                    }
                    FleetDirective::Retire { .. } => {
                        *live -= 1;
                        *parked += 1;
                        actions.push(d);
                    }
                    FleetDirective::Repin { .. } => actions.push(d),
                }
            }
        };
        // Idle at the floor: no retire below min_replicas.
        phase(&mut c, &mut actions, &mut t, &mut live, &mut parked, 8, 0);
        assert!(actions.is_empty(), "no action at the floor: {actions:?}");
        // Load step UP, sustained: dwell accumulates, one spawn, and
        // the doubled capacity (16/2 = 8 per live) lands in the
        // hysteresis gap — no second spawn, ever.
        phase(&mut c, &mut actions, &mut t, &mut live, &mut parked,
              40, 16);
        assert_eq!(actions.len(), 1, "exactly one spawn: {actions:?}");
        assert!(
            matches!(&actions[0], FleetDirective::Spawn { profile }
                     if profile.name == "economy"),
            "{actions:?}"
        );
        assert_eq!(live, 2);
        // Load step DOWN, sustained: one retire back to the floor
        // (2/2 = 1 under the retire band; at the floor 2/1 = 2 sits in
        // the gap and min_replicas guards besides).
        phase(&mut c, &mut actions, &mut t, &mut live, &mut parked,
              40, 2);
        assert_eq!(actions.len(), 2, "exactly one retire: {actions:?}");
        assert!(matches!(actions[1], FleetDirective::Retire { .. }),
                "{actions:?}");
        assert_eq!(live, 1);
        // And quiet stays quiet.
        phase(&mut c, &mut actions, &mut t, &mut live, &mut parked, 8, 0);
        assert_eq!(actions.len(), 2, "stable after the cycle: {actions:?}");
    }

    #[test]
    fn down_replica_counts_as_lost_capacity_and_spawns_cover() {
        use crate::service::replica::Health;
        let mut cfg = band_cfg();
        cfg.dwell_decisions = 1;
        let mut c = SlaAutoscaler::new(
            cfg,
            profile_by_name("economy").unwrap(),
        )
        .unwrap();
        // Two live replicas sharing backlog 12 → 6 per live: in the
        // hysteresis gap, hold.
        let mut o = obs(0.0, 2, 1, 12);
        assert_eq!(o.live(), 2);
        assert_eq!(c.decide(&o), FleetDirective::Hold);
        // Replica 1 crashes: same offered load, but per-routable
        // backlog doubles past the spawn band → the autoscaler spawns
        // to cover the loss exactly like a load step.
        o.loads[1].health = Health::Down;
        o.now = 10.0;
        assert_eq!(o.live(), 1, "a down replica is lost capacity");
        assert!(matches!(c.decide(&o),
                         FleetDirective::Spawn { .. }));
        // And an underloaded fleet never "retires" the down replica —
        // it takes no traffic, so parking it would waste the action.
        let mut c2 = SlaAutoscaler::new(
            {
                let mut cfg = band_cfg();
                cfg.dwell_decisions = 1;
                cfg.min_replicas = 1;
                cfg
            },
            profile_by_name("economy").unwrap(),
        )
        .unwrap();
        let mut o = obs(0.0, 3, 0, 0);
        o.loads[2].health = Health::Down;
        o.loads[0].cost_unit = 1.0;
        o.loads[1].cost_unit = 2.0;
        assert_eq!(c2.decide(&o),
                   FleetDirective::Retire { replica: 1 },
                   "retire picks the priciest ROUTABLE replica");
    }

    #[test]
    fn autoscaler_retires_most_expensive_and_respects_ttft() {
        let mut cfg = band_cfg();
        cfg.ttft_targets = [Some(0.2), None, None];
        cfg.dwell_decisions = 1;
        let mut c = SlaAutoscaler::new(
            cfg,
            profile_by_name("economy").unwrap(),
        )
        .unwrap();
        // TTFT breach alone (backlog fine) must trigger a spawn.
        let mut o = obs(0.0, 1, 1, 0);
        o.class_ttft_p95[0] = 0.19; // > 0.9 * 0.2
        assert!(matches!(c.decide(&o), FleetDirective::Spawn { .. }));
        // Past the cooldown, an underloaded fleet retires the most
        // expensive live replica (ties to the higher index).
        let mut o = obs(10.0, 3, 0, 0);
        o.loads[0].cost_unit = 1.0;
        o.loads[1].cost_unit = 1.5;
        o.loads[2].cost_unit = 1.5;
        assert_eq!(c.decide(&o),
                   FleetDirective::Retire { replica: 2 });
        // A TTFT p95 inside the retire guard band blocks retirement.
        let mut c2 = SlaAutoscaler::new(
            {
                let mut cfg = band_cfg();
                cfg.ttft_targets = [Some(0.2), None, None];
                cfg.dwell_decisions = 1;
                cfg
            },
            profile_by_name("economy").unwrap(),
        )
        .unwrap();
        let mut o = obs(0.0, 2, 0, 0);
        o.class_ttft_p95[0] = 0.15; // above 0.5 * 0.2 → not "comfortable"
        assert_eq!(c2.decide(&o), FleetDirective::Hold);
    }

    #[test]
    fn fleet_scale_parks_and_reopens_zero_loss() {
        let profiles = vec![
            profile_by_name("baseline").unwrap(),
            profile_by_name("economy").unwrap(),
        ];
        let mk = {
            let profiles = profiles.clone();
            move |i: usize| {
                ServiceBuilder::new(tiny_real(), cpu_host())
                    .eta_tokens(100_000)
                    .profile(profiles[i].clone())
            }
        };
        let set = Arc::new(
            ReplicaSet::build(2, RoutePolicy::LeastLoaded, mk).unwrap(),
        );
        let fleet = Fleet::new(set.clone(), profiles,
                               FleetPolicyKind::Manual)
            .unwrap();
        assert_eq!(fleet.stats().live, 2);
        // Scaling down parks the most expensive live replica:
        // baseline (1.0) parks, economy (0.55) keeps serving.
        assert_eq!(fleet.scale(1).unwrap(), 1);
        let stats = fleet.stats();
        assert_eq!(stats.live, 1);
        assert!(stats.parked[0], "baseline (more expensive) parked");
        assert!(!stats.parked[1]);
        // The set still serves through the live replica — zero loss.
        let h = set.submit(GenRequest::from_text("still on", 2)).unwrap();
        assert_eq!(h.wait().unwrap().n_tokens, 2);
        // Scale back up reopens the parked replica.
        assert_eq!(fleet.scale(2).unwrap(), 2);
        assert_eq!(fleet.stats().live, 2);
        // Bad targets refuse.
        assert!(fleet.scale(0).is_err());
        assert!(fleet.scale(3).is_err());
        set.shutdown();
    }

    #[test]
    fn fleet_tick_executes_spawn_against_the_parked_pool() {
        let profiles = vec![
            profile_by_name("baseline").unwrap(),
            profile_by_name("economy").unwrap(),
        ];
        let mk = {
            let profiles = profiles.clone();
            move |i: usize| {
                ServiceBuilder::new(tiny_real(), cpu_host())
                    .eta_tokens(100_000)
                    .profile(profiles[i].clone())
                    .paused(true)
            }
        };
        let set = Arc::new(
            ReplicaSet::build(2, RoutePolicy::LeastLoaded, mk).unwrap(),
        );
        let cfg = FleetConfig {
            spawn_backlog: 3.0,
            retire_backlog: 0.5,
            dwell_decisions: 1,
            cooldown: 0.0,
            max_replicas: 2,
            ..FleetConfig::default()
        };
        let fleet = Fleet::new(
            set.clone(),
            profiles,
            FleetPolicyKind::Autoscale(cfg),
        )
        .unwrap();
        // scale(1) parks the most expensive replica: baseline (1.0)
        // parks, economy (0.55) keeps serving.
        assert_eq!(fleet.scale(1).unwrap(), 1);
        assert!(fleet.stats().parked[0]);
        // Pile waiting work onto the live (paused) replica…
        let mut handles = Vec::new();
        for _ in 0..6 {
            handles.push(
                set.replica(1)
                    .submit(GenRequest::from_text("q", 1))
                    .unwrap(),
            );
        }
        // …and wait for its snapshot to show the backlog.
        let deadline = std::time::Instant::now()
            + std::time::Duration::from_secs(5);
        while set.replica(1).snapshot().waiting < 6 {
            assert!(std::time::Instant::now() < deadline,
                    "backlog never published");
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        // The tick observes the overload and spawns: the request asks
        // for "economy" (cheapest in the pool), but only baseline is
        // parked — the fallback reopens it rather than holding.
        let d = fleet.tick(0.0).unwrap();
        assert!(matches!(&d, FleetDirective::Spawn { profile }
                         if profile.name == "economy"),
                "{d:?}");
        let stats = fleet.stats();
        assert_eq!(stats.live, 2, "spawn reopened the parked replica");
        assert_eq!(stats.log.last().unwrap().directive, "spawn(economy)");
        assert!(stats.log.last().unwrap().applied);
        // Manual policy swap goes back to hold.
        fleet.set_policy(FleetPolicyKind::Manual).unwrap();
        assert_eq!(fleet.tick(1.0).unwrap(), FleetDirective::Hold);
        assert_eq!(fleet.policy_label(), "manual");
        set.shutdown();
    }
}
