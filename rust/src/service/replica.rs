//! Replica tier — one submission front door over N independently
//! scheduled [`Service`] replicas.
//!
//! The paper's capacity story is measured on one engine; this module is
//! the horizontal-scale layer above it (the standard split in serving
//! systems: instance-level request routing over iteration-level
//! batching). Each replica is a full `Service` — its own engine-loop
//! thread, scheduler, KV pool and controller — and the [`ReplicaSet`]
//! routes typed submissions across them with a pluggable
//! [`RoutePolicy`]:
//!
//! * **round-robin** — cheapest; ignores load.
//! * **least-loaded** — per-replica backlog (waiting + running +
//!   resuming, off the live snapshot); backlog ties break on the
//!   submitting class's **per-class SLA budget** (the replica with the
//!   lowest attributed decode p95 for that class wins — see
//!   [`ReplicaLoad::class_p95`]), then KV headroom.
//! * **class-pinned:R** — interactive traffic pinned to the first `R`
//!   replicas (its reserved latency partition), standard/batch traffic
//!   least-loaded over the rest; each class falls back to the other
//!   partition only when its own is entirely draining. Each partition's
//!   controller tunes independently via
//!   [`ReplicaSet::reconfigure_partitions`] (e.g. a tight
//!   `per-class-sla(interactive=50)` on the reserved partition, plain
//!   Algorithm 1 on the rest).
//! * **capability:L** — heterogeneous-fleet aware: interactive traffic
//!   prefers the fastest decoders ([`ReplicaLoad::decode_speed`]),
//!   prompts of `L`+ tokens prefer the biggest KV pools
//!   ([`ReplicaLoad::kv_total_blocks`]), everything else is
//!   least-loaded. Ties fall through to the least-loaded criteria
//!   (backlog, per-class decode p95, per-class TTFT p95, KV headroom).
//!
//! Policies route on a [`RouteKey`] — the submitting class plus the
//! prompt length — so capability routing can see prompt size without
//! the policies growing bespoke signatures.
//!
//! Request ids are namespaced per replica (replica `k` of `n` allocates
//! `k+1, k+1+n, …` — see [`super::ServiceBuilder::request_ids`]), so a
//! [`ReplicaSet::cancel`] routes by `(id-1) mod n` without any shared
//! map, and per-request replica attribution is [`ReplicaSet::replica_of`].
//!
//! Rolling restart is a first-class op built on the drain primitive:
//! [`ReplicaSet::rolling_restart`] walks the set draining one replica at
//! a time (the router keeps dispatching to the others — a draining
//! replica is skipped, so accepted work is never failed), hot-swaps its
//! controller, reopens it, and advances. Zero requests are lost or hung
//! across the rotation; `rust/tests/test_replica.rs` asserts it.
//!
//! The chaos layer adds *unplanned*-failure handling on top: each
//! replica carries a [`Health`] state (`Healthy → Suspect → Down →
//! Recovering`) in a [`HealthTracker`], driven by a straggler detector
//! over the per-replica decode p95s (a replica whose p95 exceeds a
//! configurable multiple of the fleet median for a dwell window turns
//! `Suspect` — hysteresis like the autoscaler bands) plus hard error
//! signals (a dead worker marks its replica `Down`). Routing excludes
//! `Suspect`/`Down` replicas exactly like draining ones; when *every*
//! live replica is unhealthy the router degrades to health-blind
//! ordering rather than rejecting — serving on a suspect replica beats
//! serving on none. See DESIGN.md "Chaos layer".

use super::{
    GenRequest, Service, ServiceBuilder, ServiceSnapshot, SubmissionHandle,
    SubmitError,
};
use crate::config::PolicyKind;
use crate::request::{PriorityClass, RequestId};
use anyhow::{anyhow, bail, Result};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// What the route policies see of one submission: the priority class
/// plus the prompt length (capability routing sends long prompts to
/// big-KV replicas). `From<PriorityClass>` gives a zero-length key for
/// call sites that only care about class.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RouteKey {
    pub class: PriorityClass,
    /// Prompt length in tokens (0 when unknown).
    pub prompt_len: usize,
}

impl RouteKey {
    pub fn new(class: PriorityClass, prompt_len: usize) -> Self {
        RouteKey { class, prompt_len }
    }
}

impl From<PriorityClass> for RouteKey {
    fn from(class: PriorityClass) -> Self {
        RouteKey { class, prompt_len: 0 }
    }
}

/// How the front door picks a replica for each submission.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Rotate over the replicas in index order.
    RoundRobin,
    /// Smallest backlog wins (waiting + running + resuming off the live
    /// snapshot); ties go to the replica with the most per-class SLA
    /// headroom for the submitting class (lowest attributed decode p95
    /// from the replica snapshots, then lowest live TTFT p95), then
    /// more free KV blocks, then the lower index.
    LeastLoaded,
    /// Interactive requests go least-loaded over replicas
    /// `[0, reserved)`; standard/batch go least-loaded over
    /// `[reserved, n)`. A class falls back to the other partition only
    /// when its own is entirely draining.
    ClassPinned { reserved: usize },
    /// Heterogeneous-fleet routing: interactive requests prefer the
    /// fastest decoders ([`ReplicaLoad::decode_speed`] descending),
    /// prompts of `long_prompt`+ tokens prefer the biggest KV pools
    /// ([`ReplicaLoad::kv_total_blocks`] descending), everything else
    /// routes least-loaded. All ties fall through to the least-loaded
    /// criteria, so a homogeneous fleet degrades to `least-loaded`.
    Capability { long_prompt: u32 },
}

/// Default long-prompt threshold for `capability` routing (tokens).
pub const DEFAULT_LONG_PROMPT: u32 = 512;

impl RoutePolicy {
    /// Parse a CLI/wire label: `round-robin` | `least-loaded` |
    /// `class-pinned:R` | `capability[:L]` (L defaults to
    /// [`DEFAULT_LONG_PROMPT`] tokens).
    pub fn parse(s: &str) -> Result<Self> {
        let s = s.trim();
        if let Some(rest) = s.strip_prefix("class-pinned:") {
            return Ok(RoutePolicy::ClassPinned { reserved: rest.parse()? });
        }
        if let Some(rest) = s.strip_prefix("capability:") {
            return Ok(RoutePolicy::Capability {
                long_prompt: rest.parse()?,
            });
        }
        Ok(match s {
            "round-robin" | "rr" => RoutePolicy::RoundRobin,
            "least-loaded" | "ll" => RoutePolicy::LeastLoaded,
            "capability" | "cap" => RoutePolicy::Capability {
                long_prompt: DEFAULT_LONG_PROMPT,
            },
            other => bail!(
                "unknown route policy '{other}' (want round-robin|\
                 least-loaded|class-pinned:R|capability[:L])"
            ),
        })
    }

    pub fn label(&self) -> String {
        match self {
            RoutePolicy::RoundRobin => "round-robin".into(),
            RoutePolicy::LeastLoaded => "least-loaded".into(),
            RoutePolicy::ClassPinned { reserved } => {
                format!("class-pinned:{reserved}")
            }
            RoutePolicy::Capability { long_prompt } => {
                format!("capability:{long_prompt}")
            }
        }
    }

    /// Structural validation against a set size (wire input reaches
    /// this, so bad shapes must be errors, not panics downstream).
    pub fn validate(&self, n_replicas: usize) -> Result<()> {
        match self {
            RoutePolicy::ClassPinned { reserved } => {
                if *reserved == 0 || *reserved >= n_replicas {
                    bail!(
                        "class-pinned needs 0 < reserved < n_replicas \
                         (reserved={reserved}, n_replicas={n_replicas})"
                    );
                }
            }
            RoutePolicy::Capability { long_prompt } => {
                if *long_prompt == 0 {
                    bail!("capability needs a long-prompt threshold >= 1");
                }
            }
            _ => {}
        }
        Ok(())
    }

    /// Dispatch preference for one request: replica indices, best first,
    /// draining/down replicas excluded (empty = nowhere to route). `rr`
    /// is the caller's monotone submission counter (consumed by
    /// round-robin, ignored otherwise). Pure over the load snapshot so
    /// the live router and the virtual-time driver share one policy.
    pub fn order(&self, key: impl Into<RouteKey>, loads: &[ReplicaLoad],
                 rr: usize) -> Vec<usize> {
        let key = key.into();
        let class = key.class;
        match self {
            RoutePolicy::RoundRobin => {
                if loads.is_empty() {
                    return Vec::new();
                }
                let n = loads.len();
                let start = rr % n;
                (0..n)
                    .map(|k| (start + k) % n)
                    .filter(|&i| loads[i].routable())
                    .collect()
            }
            RoutePolicy::LeastLoaded => {
                let up: Vec<usize> = (0..loads.len())
                    .filter(|&i| loads[i].routable())
                    .collect();
                least_loaded(&up, loads, class.rank())
            }
            RoutePolicy::ClassPinned { reserved } => {
                let (own, other): (Vec<usize>, Vec<usize>) =
                    (0..loads.len())
                        .filter(|&i| loads[i].routable())
                        .partition(|&i| {
                            (i < *reserved)
                                == (class == PriorityClass::Interactive)
                        });
                let mut out = least_loaded(&own, loads, class.rank());
                out.extend(least_loaded(&other, loads, class.rank()));
                out
            }
            RoutePolicy::Capability { long_prompt } => {
                let mut v: Vec<usize> = (0..loads.len())
                    .filter(|&i| loads[i].routable())
                    .collect();
                let rank = class.rank();
                if class == PriorityClass::Interactive {
                    // Latency-bound work onto the fastest decoders.
                    v.sort_by(|&a, &b| {
                        loads[b]
                            .decode_speed
                            .total_cmp(&loads[a].decode_speed)
                            .then(load_cmp(&loads[a], &loads[b], rank))
                            .then(a.cmp(&b))
                    });
                } else if key.prompt_len >= *long_prompt as usize {
                    // Long prompts onto the biggest KV pools.
                    v.sort_by(|&a, &b| {
                        loads[b]
                            .kv_total_blocks
                            .cmp(&loads[a].kv_total_blocks)
                            .then(load_cmp(&loads[a], &loads[b], rank))
                            .then(a.cmp(&b))
                    });
                } else {
                    v = least_loaded(&v, loads, rank);
                }
                v
            }
        }
    }

    /// First choice of [`Self::order`], if any replica is routable.
    pub fn pick(&self, key: impl Into<RouteKey>, loads: &[ReplicaLoad],
                rr: usize) -> Option<usize> {
        self.order(key, loads, rr).first().copied()
    }
}

/// The shared load comparison (less = better) for a request of class
/// rank `rank`: backlog, then per-class SLA headroom (lower attributed
/// decode p95 for that class = more headroom), then lower live per-class
/// TTFT p95, then free KV blocks.
fn load_cmp(a: &ReplicaLoad, b: &ReplicaLoad, rank: usize)
            -> std::cmp::Ordering {
    a.backlog()
        .cmp(&b.backlog())
        .then(a.class_p95[rank].total_cmp(&b.class_p95[rank]))
        .then(a.class_ttft_p95[rank].total_cmp(&b.class_ttft_p95[rank]))
        .then(b.kv_free_blocks.cmp(&a.kv_free_blocks))
}

/// Sort candidate replicas best-first for a request of class rank
/// `rank` by [`load_cmp`], then index.
fn least_loaded(idx: &[usize], loads: &[ReplicaLoad], rank: usize)
                -> Vec<usize> {
    let mut v = idx.to_vec();
    v.sort_by(|&a, &b| {
        load_cmp(&loads[a], &loads[b], rank).then(a.cmp(&b))
    });
    v
}

/// Per-replica health, as the router consumes it. Only [`Health::Healthy`]
/// and [`Health::Recovering`] replicas are routing candidates.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Health {
    /// Serving normally.
    #[default]
    Healthy,
    /// Straggler suspicion: the replica's decode p95 exceeded the
    /// detector's multiple of the fleet median for the dwell window.
    /// Excluded from routing until it observes clean again.
    Suspect,
    /// Hard failure (dead worker, crash fault, operator action).
    /// Excluded from routing until explicitly recovered.
    Down,
    /// Post-`Down` probation: routable again, promoted back to
    /// `Healthy` after a clean dwell window.
    Recovering,
}

impl Health {
    /// Whether the router may dispatch new work to this replica.
    pub fn routable(self) -> bool {
        matches!(self, Health::Healthy | Health::Recovering)
    }

    pub fn label(self) -> &'static str {
        match self {
            Health::Healthy => "healthy",
            Health::Suspect => "suspect",
            Health::Down => "down",
            Health::Recovering => "recovering",
        }
    }
}

/// Straggler-detection knobs for the [`HealthTracker`]. Dwell windows
/// give the detector hysteresis (like the autoscaler bands): one noisy
/// p95 sample neither condemns nor absolves a replica.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HealthPolicy {
    /// A replica straggles when its decode p95 exceeds this multiple of
    /// the fleet's (lower) median p95.
    pub suspect_factor: f64,
    /// Consecutive straggling observations before `Healthy → Suspect`.
    pub suspect_dwell: u32,
    /// Consecutive clean observations before `Suspect`/`Recovering`
    /// promote back to `Healthy`.
    pub recover_dwell: u32,
}

impl Default for HealthPolicy {
    fn default() -> Self {
        HealthPolicy {
            suspect_factor: 3.0,
            suspect_dwell: 3,
            recover_dwell: 3,
        }
    }
}

/// The per-replica [`Health`] state machine: `Healthy → Suspect` on a
/// sustained straggler signal, any state `→ Down` on a hard failure,
/// `Down → Recovering` on explicit recovery, `Suspect`/`Recovering
/// → Healthy` after a clean dwell window. Pure over the observed
/// per-replica p95s, so the live router and the virtual-time chaos
/// driver share one detector.
#[derive(Debug, Clone)]
pub struct HealthTracker {
    policy: HealthPolicy,
    states: Vec<Health>,
    slow_streak: Vec<u32>,
    ok_streak: Vec<u32>,
}

impl HealthTracker {
    pub fn new(n: usize, policy: HealthPolicy) -> Self {
        HealthTracker {
            policy,
            states: vec![Health::Healthy; n],
            slow_streak: vec![0; n],
            ok_streak: vec![0; n],
        }
    }

    pub fn len(&self) -> usize {
        self.states.len()
    }

    pub fn is_empty(&self) -> bool {
        self.states.is_empty()
    }

    pub fn state(&self, i: usize) -> Health {
        self.states[i]
    }

    pub fn states(&self) -> &[Health] {
        &self.states
    }

    pub fn routable(&self, i: usize) -> bool {
        self.states[i].routable()
    }

    /// Swap the detection knobs; states and streaks carry over.
    pub fn set_policy(&mut self, policy: HealthPolicy) {
        self.policy = policy;
    }

    pub fn policy(&self) -> HealthPolicy {
        self.policy
    }

    /// Hard-failure signal (dead worker, crash fault, operator action):
    /// the replica leaves the routing set until [`Self::mark_recovering`].
    pub fn mark_down(&mut self, i: usize) {
        self.states[i] = Health::Down;
        self.slow_streak[i] = 0;
        self.ok_streak[i] = 0;
    }

    /// Begin recovery of a `Down` replica: routable again on probation;
    /// a clean dwell window promotes it back to `Healthy`. No-op for
    /// replicas that are not `Down`.
    pub fn mark_recovering(&mut self, i: usize) {
        if self.states[i] == Health::Down {
            self.states[i] = Health::Recovering;
            self.slow_streak[i] = 0;
            self.ok_streak[i] = 0;
        }
    }

    /// One straggler-detection pass over the per-replica decode p95s
    /// (0.0 = no samples). The fleet median is the lower median of the
    /// non-`Down` replicas with samples, so with two replicas the
    /// straggler is compared against the healthy one, not itself. At
    /// least two sampled replicas are needed — a median of one is the
    /// replica itself. Returns the replicas that just turned `Suspect`
    /// (the hedging trigger).
    pub fn observe(&mut self, p95: &[f64]) -> Vec<usize> {
        debug_assert_eq!(p95.len(), self.states.len());
        let mut sample: Vec<f64> = (0..self.states.len())
            .filter(|&i| self.states[i] != Health::Down && p95[i] > 0.0)
            .map(|i| p95[i])
            .collect();
        let median = if sample.len() >= 2 {
            sample.sort_by(f64::total_cmp);
            sample[(sample.len() - 1) / 2]
        } else {
            0.0
        };
        let mut newly_suspect = Vec::new();
        for i in 0..self.states.len() {
            if self.states[i] == Health::Down {
                continue;
            }
            let straggling = median > 0.0
                && p95[i] > self.policy.suspect_factor * median;
            if straggling {
                self.slow_streak[i] += 1;
                self.ok_streak[i] = 0;
                if self.slow_streak[i] >= self.policy.suspect_dwell
                    && self.states[i] != Health::Suspect
                {
                    self.states[i] = Health::Suspect;
                    newly_suspect.push(i);
                }
            } else {
                self.ok_streak[i] += 1;
                self.slow_streak[i] = 0;
                if self.ok_streak[i] >= self.policy.recover_dwell
                    && self.states[i] != Health::Healthy
                {
                    self.states[i] = Health::Healthy;
                }
            }
        }
        newly_suspect
    }
}

/// Point-in-time load view of one replica, as the route policies consume
/// it. Built from [`ServiceSnapshot`]s on the live path and from
/// scheduler queue lengths on the virtual-time driver path.
#[derive(Debug, Clone, Copy)]
pub struct ReplicaLoad {
    pub waiting: u32,
    pub running: u32,
    pub resuming: u32,
    /// Submissions the router dispatched to this replica that are not
    /// yet visible in its published snapshot (the snapshot refreshes
    /// once per engine-loop iteration; without this correction a burst
    /// inside one refresh window would herd onto one replica). Zero on
    /// the virtual-time driver path, which reads queues synchronously.
    pub in_flight_to: u32,
    pub kv_free_blocks: usize,
    /// Total KV pool size — the capability router's long-prompt signal
    /// (heterogeneous fleets size pools per [`ReplicaProfile`]).
    ///
    /// [`ReplicaProfile`]: crate::config::ReplicaProfile
    pub kv_total_blocks: usize,
    /// The replica profile's relative decode speed (1.0 = baseline) —
    /// the capability router's interactive signal.
    pub decode_speed: f64,
    /// The replica profile's relative cost per replica-second — the
    /// fleet controller's retire-preference signal.
    pub cost_unit: f64,
    /// Recent decode-latency p95 attributed per class (seconds, indexed
    /// by [`PriorityClass::rank`]; 0.0 until that class has decoded on
    /// the replica) — the per-class SLA budget signal `least-loaded`
    /// tie-breaks on.
    pub class_p95: [f64; PriorityClass::COUNT],
    /// Live per-class TTFT p95 (seconds; 0.0 until the class has seen a
    /// first token on the replica).
    pub class_ttft_p95: [f64; PriorityClass::COUNT],
    /// Draining or shut down: not a routing candidate.
    pub draining: bool,
    /// Chaos-layer health; `Suspect`/`Down` replicas are excluded from
    /// routing like draining ones (but see the health-blind degraded
    /// mode in [`ReplicaSet::submit_routed`]).
    pub health: Health,
}

impl Default for ReplicaLoad {
    /// Neutral-profile idle replica (decode speed and cost at the
    /// baseline 1.0 — zeros would misroute capability traffic).
    fn default() -> Self {
        ReplicaLoad {
            waiting: 0,
            running: 0,
            resuming: 0,
            in_flight_to: 0,
            kv_free_blocks: 0,
            kv_total_blocks: 0,
            decode_speed: 1.0,
            cost_unit: 1.0,
            class_p95: [0.0; PriorityClass::COUNT],
            class_ttft_p95: [0.0; PriorityClass::COUNT],
            draining: false,
            health: Health::Healthy,
        }
    }
}

impl ReplicaLoad {
    /// Requests somewhere inside the replica (including dispatches the
    /// snapshot has not caught up with) — the least-loaded score.
    pub fn backlog(&self) -> u64 {
        self.waiting as u64
            + self.running as u64
            + self.resuming as u64
            + self.in_flight_to as u64
    }

    /// Routing candidate: neither draining nor health-excluded.
    pub fn routable(&self) -> bool {
        !self.draining && self.health.routable()
    }
}

/// Why a [`ReplicaSet::rolling_restart`] stopped, identifying the
/// replica that failed its rotation step. Downcastable from the anyhow
/// error (like [`SubmitError`]), so operators and the wire layer can
/// report *which* replica aborted the rotation instead of a generic
/// failure — replicas before it are already rotated and reopened,
/// replicas after it untouched.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RollingError {
    /// The replica's drain failed (its worker died mid-drain).
    Drain { replica: usize, message: String },
    /// The drain went through but the controller hot-swap failed; the
    /// replica is left drained (not reopened) so it cannot serve under
    /// the stale controller.
    Reconfigure { replica: usize, message: String },
    /// The replica's worker was already gone — draining a dead worker
    /// would hang, so the rotation refuses it up front.
    Dead { replica: usize },
}

impl RollingError {
    /// The replica whose rotation step failed.
    pub fn replica(&self) -> usize {
        match self {
            RollingError::Drain { replica, .. }
            | RollingError::Reconfigure { replica, .. }
            | RollingError::Dead { replica } => *replica,
        }
    }
}

impl std::fmt::Display for RollingError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RollingError::Drain { replica, message } => {
                write!(f, "rolling restart: drain of replica {replica} \
                           failed: {message}")
            }
            RollingError::Reconfigure { replica, message } => {
                write!(f, "rolling restart: reconfigure of replica \
                           {replica} failed (left drained): {message}")
            }
            RollingError::Dead { replica } => {
                write!(f, "rolling restart: replica {replica} is shut \
                           down — rotation refused")
            }
        }
    }
}

impl std::error::Error for RollingError {}

/// N `Service` replicas behind one submission front door. Cheap to share
/// behind an `Arc`; dropping it shuts every replica down (via the
/// `Service` drops).
///
/// ```
/// use dynabatch::config::presets::{cpu_host, tiny_real};
/// use dynabatch::service::{
///     GenRequest, ReplicaSet, RoutePolicy, ServiceBuilder,
/// };
///
/// let set = ReplicaSet::build(2, RoutePolicy::LeastLoaded, |_replica| {
///     ServiceBuilder::new(tiny_real(), cpu_host()).eta_tokens(100_000)
/// })?;
/// let (replica, handle) =
///     set.submit_routed(GenRequest::from_text("hi", 2))?;
/// assert!(replica < set.len());
/// assert_eq!(handle.wait()?.n_tokens, 2);
/// set.shutdown();
/// # Ok::<(), anyhow::Error>(())
/// ```
pub struct ReplicaSet {
    replicas: Vec<Arc<Service>>,
    route: RoutePolicy,
    /// Monotone submission counter feeding round-robin.
    rr: AtomicUsize,
    /// Cumulative submissions this router dispatched per replica —
    /// compared against the snapshot's cumulative seen count to credit
    /// not-yet-published dispatches to a replica's load
    /// ([`ReplicaLoad::in_flight_to`]), so a burst inside one snapshot
    /// refresh window spreads instead of herding. Known limit: a
    /// replica that also receives *direct* submissions (bypassing the
    /// router) inflates the seen count permanently, saturating its
    /// credit to zero — routing degrades to the snapshot-only view for
    /// that replica, never below it. Production traffic goes through
    /// the router; direct submits are a test/embedding convenience.
    routed: Vec<AtomicU64>,
    /// Serializes drain/reopen/rotation ops: a concurrent rolling
    /// restart must not interleave its drain/reopen sequence with
    /// another rotation or an operator drain — an unsynchronized
    /// `reopen` under a still-blocked `drain` lets new work postpone
    /// that drain indefinitely, and two interleaved rotations can have
    /// every replica draining at once. Late callers queue.
    rotation: Mutex<()>,
    /// Chaos-layer per-replica health (straggler detection + hard
    /// failure signals); overlaid onto [`Self::loads`] so every route
    /// policy excludes unhealthy replicas for free.
    health: Mutex<HealthTracker>,
}

impl ReplicaSet {
    /// Build `n` replicas from a per-replica [`ServiceBuilder`] factory.
    /// The factory's builder is namespaced automatically (replica `k` →
    /// ids `k+1, k+1+n, …`), so cancel routing and per-request replica
    /// attribution work out of the box.
    pub fn build<F>(n: usize, route: RoutePolicy, mut mk: F)
                    -> Result<ReplicaSet>
    where
        F: FnMut(usize) -> ServiceBuilder,
    {
        if n == 0 {
            bail!("a replica set needs at least one replica");
        }
        route.validate(n)?;
        let mut replicas = Vec::with_capacity(n);
        for i in 0..n {
            let svc = mk(i)
                .request_ids(i as u64 + 1, n as u64)
                .build()
                .map_err(|e| anyhow!("building replica {i}: {e:#}"))?;
            replicas.push(Arc::new(svc));
        }
        let routed = (0..n).map(|_| AtomicU64::new(0)).collect();
        Ok(ReplicaSet {
            replicas,
            route,
            rr: AtomicUsize::new(0),
            routed,
            rotation: Mutex::new(()),
            health: Mutex::new(HealthTracker::new(
                n,
                HealthPolicy::default(),
            )),
        })
    }

    /// Wrap already-built services. Callers are responsible for id
    /// namespacing ([`ServiceBuilder::request_ids`]) when `n > 1`; the
    /// single-service case (the compat server path) needs none.
    pub fn from_services(services: Vec<Service>, route: RoutePolicy)
                         -> Result<ReplicaSet> {
        if services.is_empty() {
            bail!("a replica set needs at least one replica");
        }
        route.validate(services.len())?;
        let replicas: Vec<Arc<Service>> =
            services.into_iter().map(Arc::new).collect();
        let routed =
            (0..replicas.len()).map(|_| AtomicU64::new(0)).collect();
        let n = replicas.len();
        Ok(ReplicaSet {
            replicas,
            route,
            rr: AtomicUsize::new(0),
            routed,
            rotation: Mutex::new(()),
            health: Mutex::new(HealthTracker::new(
                n,
                HealthPolicy::default(),
            )),
        })
    }

    pub fn len(&self) -> usize {
        self.replicas.len()
    }

    pub fn is_empty(&self) -> bool {
        self.replicas.is_empty()
    }

    pub fn route_policy(&self) -> &RoutePolicy {
        &self.route
    }

    /// Direct access to one replica (introspection, targeted submits in
    /// tests). Panics when out of range.
    pub fn replica(&self, i: usize) -> &Service {
        &self.replicas[i]
    }

    /// Which replica owns request id `id` (the id-namespace inverse).
    pub fn replica_of(&self, id: RequestId) -> usize {
        (id.wrapping_sub(1) % self.replicas.len() as u64) as usize
    }

    /// Live load view across the set, index-aligned with the replicas.
    /// Reads only the scalar fields out of each published snapshot (no
    /// heap clones — this runs per submission) and credits this
    /// router's not-yet-published dispatches, so consecutive picks
    /// within one snapshot refresh window spread by real load.
    pub fn loads(&self) -> Vec<ReplicaLoad> {
        let mut loads: Vec<ReplicaLoad> = self
            .replicas
            .iter()
            .zip(self.routed.iter())
            .map(|(s, routed)| {
                let snap = s.shared.snapshot.lock().unwrap();
                // Everything the snapshot has ever seen (live +
                // terminal); router dispatches beyond it are in flight
                // toward the replica. Direct (non-router) submissions
                // make `seen` run ahead — saturate to zero, falling
                // back to the snapshot-only view.
                let seen = snap.finished
                    + snap.rejected
                    + snap.shed
                    + snap.cancelled
                    + snap.waiting as u64
                    + snap.running as u64
                    + snap.resuming as u64;
                let in_flight_to = routed
                    .load(Ordering::SeqCst)
                    .saturating_sub(seen)
                    .min(u32::MAX as u64) as u32;
                ReplicaLoad {
                    waiting: snap.waiting,
                    running: snap.running,
                    resuming: snap.resuming,
                    in_flight_to,
                    kv_free_blocks: snap.kv_free_blocks,
                    kv_total_blocks: snap.kv_total_blocks,
                    decode_speed: snap.decode_speed,
                    cost_unit: snap.cost_unit,
                    class_p95: snap.class_lat_p95,
                    class_ttft_p95: snap.class_ttft_p95,
                    // The snapshot's flag is published once per loop
                    // iteration; read the authoritative flags so
                    // routing reacts to begin_drain/shutdown
                    // immediately.
                    draining: s.is_draining() || s.is_shutdown(),
                    health: Health::Healthy, // overlaid below
                }
            })
            .collect();
        let health = self.health.lock().unwrap();
        for (i, l) in loads.iter_mut().enumerate() {
            l.health = health.state(i);
        }
        loads
    }

    /// Route and submit. Skips draining replicas; when the routed
    /// replica refuses with a typed [`SubmitError`] (a drain race), the
    /// remaining candidates are tried in preference order. Fails with
    /// [`SubmitError::Draining`] only when the whole set is draining.
    pub fn submit(&self, req: GenRequest) -> Result<SubmissionHandle> {
        self.submit_routed(req).map(|(_, h)| h)
    }

    /// [`Self::submit`] plus the chosen replica index. Validation
    /// happens once, inside `Service::submit`. When a whole candidate
    /// pass is refused by drain races (a rotation can reopen one
    /// replica and start draining another between our load read and
    /// the submit landing), the loads are re-read and the pass retried
    /// — bounded, so a pathological flag flutter cannot livelock the
    /// submitter.
    pub fn submit_routed(&self, req: GenRequest)
                         -> Result<(usize, SubmissionHandle)> {
        const MAX_ROUTE_PASSES: usize = 8;
        let mut last_err: Option<anyhow::Error> = None;
        let key = RouteKey::new(req.class, req.prompt_tokens.len());
        for _pass in 0..MAX_ROUTE_PASSES {
            let loads = self.loads();
            let rr = self.rr.fetch_add(1, Ordering::Relaxed);
            let mut order = self.route.order(key, &loads, rr);
            if order.is_empty() {
                // Degraded mode: when every live replica is merely
                // unhealthy (suspect/down, not draining), route
                // health-blind rather than reject — a dead worker
                // still refuses with a typed error below, so this
                // only ever lands work on a serving replica.
                let mut blind = loads.clone();
                for l in &mut blind {
                    l.health = Health::Healthy;
                }
                order = self.route.order(key, &blind, rr);
            }
            if order.is_empty() {
                break; // the whole set is draining
            }
            for &i in &order {
                // The clone (one prompt-token Vec copy) buys the
                // bounded retry passes above — a refused submit cannot
                // hand the request back through the anyhow error path.
                match self.replicas[i].submit(req.clone()) {
                    Ok(h) => {
                        self.routed[i].fetch_add(1, Ordering::SeqCst);
                        return Ok((i, h));
                    }
                    Err(e) => {
                        let retryable = matches!(
                            e.downcast_ref::<SubmitError>(),
                            Some(SubmitError::Draining)
                                | Some(SubmitError::ShutDown)
                        );
                        if !retryable {
                            return Err(e);
                        }
                        // A dead worker is a health signal (a drain is
                        // planned, not a fault): stop routing to it.
                        if matches!(
                            e.downcast_ref::<SubmitError>(),
                            Some(SubmitError::ShutDown)
                        ) {
                            self.health.lock().unwrap().mark_down(i);
                        }
                        last_err = Some(e);
                    }
                }
            }
            // Every candidate of this pass hit a drain race; the set
            // may have rotated under us — re-read and go again.
        }
        Err(last_err
            .unwrap_or_else(|| anyhow::Error::new(SubmitError::Draining)))
    }

    /// Cancel any in-flight id — routed to its owning replica by the id
    /// namespace. Returns false only when the id is outside every
    /// namespace (`0`) or the owning replica's worker is gone.
    pub fn cancel(&self, id: RequestId) -> bool {
        if id == 0 {
            return false;
        }
        self.replicas[self.replica_of(id)].cancel(id)
    }

    /// Per-replica health states, index-aligned with the replicas.
    pub fn health_states(&self) -> Vec<Health> {
        self.health.lock().unwrap().states().to_vec()
    }

    /// Swap the straggler-detection knobs; current states carry over.
    pub fn set_health_policy(&self, policy: HealthPolicy) {
        self.health.lock().unwrap().set_policy(policy);
    }

    /// One straggler-detection pass over the live snapshots: each
    /// replica's signal is its worst attributed per-class decode p95.
    /// Call periodically (the server runs it on every `stats` request).
    /// Returns the replicas that just turned [`Health::Suspect`].
    pub fn observe_health(&self) -> Vec<usize> {
        let signals: Vec<f64> = self
            .snapshots()
            .iter()
            .map(|s| {
                s.class_lat_p95.iter().fold(0.0f64, |a, &b| a.max(b))
            })
            .collect();
        self.health.lock().unwrap().observe(&signals)
    }

    /// Mark a replica [`Health::Down`] (operator action or hard-failure
    /// signal): it leaves the routing set until [`Self::mark_recovering`].
    pub fn mark_down(&self, i: usize) -> Result<()> {
        self.checked(i)?;
        self.health.lock().unwrap().mark_down(i);
        Ok(())
    }

    /// Begin recovery of a `Down` replica: routable again on probation,
    /// promoted to `Healthy` after a clean dwell window.
    pub fn mark_recovering(&self, i: usize) -> Result<()> {
        self.checked(i)?;
        self.health.lock().unwrap().mark_recovering(i);
        Ok(())
    }

    /// Per-replica snapshots, index-aligned with the replicas.
    pub fn snapshots(&self) -> Vec<ServiceSnapshot> {
        self.replicas.iter().map(|s| s.snapshot()).collect()
    }

    /// Fold per-replica snapshots into one set-level view: counters and
    /// KV accounting sum, `b_t` sums (total concurrency target),
    /// `controller` is the replicas' common label (distinct labels join
    /// with `|`), `draining` means *every* replica is draining — i.e.
    /// the whole set refuses work — and the per-class latency/TTFT
    /// percentiles take the worst (max) replica, the conservative
    /// set-level SLA read (exact percentiles cannot be folded from
    /// per-replica ones; per-replica values stay attributed under
    /// `stats.replicas`). Profile fields fold fleet-wise: `profile`
    /// joins the distinct profile names with `|`, `cost_unit` sums
    /// (the fleet's cost rate in baseline-replica-seconds per second)
    /// and `decode_speed` takes the fastest replica. `kv_shared_tokens`
    /// sums; `prefix_hit_rate` takes the worst (min) replica — the set
    /// is only as warm as its coldest cache. `prefill_padded_tokens`
    /// sums; `padding_waste` takes the worst (max) replica — the
    /// honest read for "is padding eating my throughput?" across the
    /// set.
    pub fn aggregate(snaps: &[ServiceSnapshot]) -> ServiceSnapshot {
        let mut agg = ServiceSnapshot {
            draining: !snaps.is_empty(),
            // Min-folded below; empty sets report the 0.0 default.
            prefix_hit_rate: if snaps.is_empty() {
                0.0
            } else {
                f64::INFINITY
            },
            ..ServiceSnapshot::default()
        };
        let mut labels: Vec<&str> = Vec::new();
        let mut profiles: Vec<&str> = Vec::new();
        for s in snaps {
            agg.running += s.running;
            agg.waiting += s.waiting;
            for (a, b) in agg
                .waiting_by_class
                .iter_mut()
                .zip(s.waiting_by_class.iter())
            {
                *a += *b;
            }
            agg.resuming += s.resuming;
            agg.kv_used_tokens += s.kv_used_tokens;
            agg.kv_free_blocks += s.kv_free_blocks;
            agg.kv_total_blocks += s.kv_total_blocks;
            agg.kv_shared_tokens += s.kv_shared_tokens;
            // Worst replica: the set is only as warm as its coldest
            // cache, which is the honest signal for an operator asking
            // "is sharing paying off?".
            agg.prefix_hit_rate =
                agg.prefix_hit_rate.min(s.prefix_hit_rate);
            agg.prefill_padded_tokens += s.prefill_padded_tokens;
            agg.padding_waste = agg.padding_waste.max(s.padding_waste);
            agg.b_t += s.b_t;
            agg.steps += s.steps;
            agg.finished += s.finished;
            agg.rejected += s.rejected;
            agg.shed += s.shed;
            agg.cancelled += s.cancelled;
            agg.reconfigs += s.reconfigs;
            agg.draining &= s.draining;
            for rank in 0..PriorityClass::COUNT {
                agg.class_lat_p50[rank] =
                    agg.class_lat_p50[rank].max(s.class_lat_p50[rank]);
                agg.class_lat_p95[rank] =
                    agg.class_lat_p95[rank].max(s.class_lat_p95[rank]);
                agg.class_ttft_p95[rank] =
                    agg.class_ttft_p95[rank].max(s.class_ttft_p95[rank]);
            }
            agg.cost_unit += s.cost_unit;
            agg.decode_speed = agg.decode_speed.max(s.decode_speed);
            if !labels.contains(&s.controller.as_str()) {
                labels.push(s.controller.as_str());
            }
            if !s.profile.is_empty()
                && !profiles.contains(&s.profile.as_str())
            {
                profiles.push(s.profile.as_str());
            }
        }
        agg.controller = labels.join("|");
        agg.profile = profiles.join("|");
        agg
    }

    /// [`Self::aggregate`] over the live [`Self::snapshots`].
    pub fn aggregate_snapshot(&self) -> ServiceSnapshot {
        Self::aggregate(&self.snapshots())
    }

    /// Fan a controller hot-swap out to every replica. The kind is
    /// validated up front so an invalid policy swaps nothing; a
    /// mid-fan-out failure (a dead replica worker) leaves earlier
    /// replicas on the new controller and surfaces the error. Returns
    /// the (common) new controller label.
    pub fn reconfigure(&self, kind: PolicyKind) -> Result<String> {
        kind.validate()?;
        let mut label = String::new();
        for (i, s) in self.replicas.iter().enumerate() {
            label = s
                .reconfigure(kind.clone())
                .map_err(|e| anyhow!("reconfigure replica {i}: {e:#}"))?;
        }
        Ok(label)
    }

    /// Hot-swap the controller on a single replica (the wire op
    /// `set_policy` with a `replica` field). The building block for
    /// tuning `class-pinned` partitions independently — see
    /// [`Self::reconfigure_partitions`]. Returns the replica's new
    /// controller label.
    pub fn reconfigure_replica(&self, i: usize, kind: PolicyKind)
                               -> Result<String> {
        kind.validate()?;
        self.checked(i)?
            .reconfigure(kind)
            .map_err(|e| anyhow!("reconfigure replica {i}: {e:#}"))
    }

    /// Tune each `class-pinned` partition's controller independently via
    /// the per-replica reconfigure fan-out: the reserved interactive
    /// partition `[0, R)` gets `interactive`, the unreserved rest gets
    /// `others` (e.g. a tight `per-class-sla(interactive=50)` on the
    /// latency partition and plain `alg1` on the throughput partition).
    /// Fails unless the route policy is `class-pinned`. Returns the two
    /// partitions' new controller labels.
    pub fn reconfigure_partitions(&self, interactive: PolicyKind,
                                  others: PolicyKind)
                                  -> Result<(String, String)> {
        let RoutePolicy::ClassPinned { reserved } = &self.route else {
            bail!(
                "partition tuning needs the class-pinned route policy \
                 (current: {})",
                self.route.label()
            );
        };
        let reserved = *reserved;
        interactive.validate()?;
        others.validate()?;
        let mut labels = (String::new(), String::new());
        for i in 0..self.replicas.len() {
            let kind = if i < reserved {
                interactive.clone()
            } else {
                others.clone()
            };
            let label = self.reconfigure_replica(i, kind)?;
            if i < reserved {
                labels.0 = label;
            } else {
                labels.1 = label;
            }
        }
        Ok(labels)
    }

    /// Whole-set drain: stop admissions on *every* replica first (so the
    /// router cannot shuffle rejected work between half-drained
    /// replicas), then wait each out. Resolves when no replica holds
    /// in-flight work. Serialized against rotations (see `rotation`);
    /// the admission flags flip *before* queueing behind an in-progress
    /// rotation — admissions stop immediately, except that the rotation
    /// may briefly re-admit on a replica it reopens until it finishes,
    /// after which the flags are re-asserted under the lock.
    pub fn drain(&self) -> Result<()> {
        for s in &self.replicas {
            s.begin_drain();
        }
        let _turn = self.rotation.lock().unwrap();
        // A rotation that finished between the flip above and taking
        // the lock may have reopened replicas — re-assert.
        for s in &self.replicas {
            s.begin_drain();
        }
        for (i, s) in self.replicas.iter().enumerate() {
            s.drain().map_err(|e| anyhow!("drain replica {i}: {e:#}"))?;
        }
        Ok(())
    }

    /// Drain a single replica (the router stops routing to it
    /// immediately; in-flight work finishes). Blocks until drained.
    /// Serialized against rotations and other drains, with the same
    /// flag-before-lock behavior as [`Self::drain`].
    pub fn drain_replica(&self, i: usize) -> Result<()> {
        let s = self.checked(i)?;
        s.begin_drain();
        let _turn = self.rotation.lock().unwrap();
        s.begin_drain(); // re-assert if a rotation reopened it meanwhile
        s.drain()
    }

    /// Reopen a drained replica for admissions (the rejoin half of a
    /// rotation). Refuses (instead of blocking) while a drain or
    /// rotation holds the rotation lock — reopening mid-drain would
    /// let new work postpone that drain indefinitely, and callers
    /// (e.g. the server's connection read loop) must not block on it.
    pub fn reopen_replica(&self, i: usize) -> Result<()> {
        let s = self.checked(i)?;
        let _turn = self.try_rotation_turn()?;
        s.reopen();
        Ok(())
    }

    /// Reopen every replica (refuses while a drain/rotation is in
    /// progress, like [`Self::reopen_replica`]).
    pub fn reopen(&self) -> Result<()> {
        let _turn = self.try_rotation_turn()?;
        for s in &self.replicas {
            s.reopen();
        }
        Ok(())
    }

    fn try_rotation_turn(&self) -> Result<std::sync::MutexGuard<'_, ()>> {
        self.rotation.try_lock().map_err(|_| {
            anyhow!("a drain or rolling restart is in progress — \
                     reopen refused")
        })
    }

    /// Rolling restart — the first-class rotation op: for each replica
    /// in index order, drain it (the router keeps serving from the
    /// others; accepted work always finishes), hot-swap its controller
    /// when `policy` is given, reopen it, advance. Returns each
    /// replica's post-rotation controller label. With a single replica
    /// the set refuses submissions during its own window — run ≥ 2
    /// replicas for a zero-downtime rotation.
    ///
    /// A step failure surfaces as a downcastable [`RollingError`]
    /// naming the replica that aborted the rotation: replicas before it
    /// are rotated and reopened, replicas after it untouched, and a
    /// [`RollingError::Reconfigure`] leaves its replica drained so it
    /// cannot serve under the stale controller. A replica whose worker
    /// is already gone fails fast with [`RollingError::Dead`] instead
    /// of hanging its drain.
    pub fn rolling_restart(&self, policy: Option<&PolicyKind>)
                           -> Result<Vec<String>> {
        if let Some(k) = policy {
            k.validate()?;
        }
        // One rotation at a time: a concurrent rotation or drain waits
        // here instead of interleaving drain/reopen on live replicas.
        let _turn = self.rotation.lock().unwrap();
        let mut labels = Vec::with_capacity(self.replicas.len());
        for (i, s) in self.replicas.iter().enumerate() {
            if s.is_shutdown() {
                return Err(anyhow::Error::new(RollingError::Dead {
                    replica: i,
                }));
            }
            s.drain().map_err(|e| {
                anyhow::Error::new(RollingError::Drain {
                    replica: i,
                    message: format!("{e:#}"),
                })
            })?;
            let label = match policy {
                Some(k) => s.reconfigure(k.clone()).map_err(|e| {
                    anyhow::Error::new(RollingError::Reconfigure {
                        replica: i,
                        message: format!("{e:#}"),
                    })
                })?,
                None => s.snapshot().controller,
            };
            s.reopen();
            labels.push(label);
        }
        Ok(labels)
    }

    /// Pause/resume every replica's stepping loop (deterministic tests).
    pub fn pause(&self) {
        for s in &self.replicas {
            s.pause();
        }
    }

    pub fn resume(&self) {
        for s in &self.replicas {
            s.resume();
        }
    }

    pub fn is_shutdown(&self) -> bool {
        self.replicas.iter().all(|s| s.is_shutdown())
    }

    pub fn shutdown(&self) {
        for s in &self.replicas {
            s.shutdown();
        }
    }

    fn checked(&self, i: usize) -> Result<&Arc<Service>> {
        self.replicas.get(i).ok_or_else(|| {
            anyhow!("replica {i} out of range (set has {})",
                    self.replicas.len())
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn load(waiting: u32, running: u32, free: usize) -> ReplicaLoad {
        ReplicaLoad {
            waiting,
            running,
            kv_free_blocks: free,
            ..ReplicaLoad::default()
        }
    }

    #[test]
    fn parse_label_roundtrip_and_validation() {
        for p in [
            RoutePolicy::RoundRobin,
            RoutePolicy::LeastLoaded,
            RoutePolicy::ClassPinned { reserved: 2 },
        ] {
            assert_eq!(RoutePolicy::parse(&p.label()).unwrap(), p);
        }
        assert_eq!(RoutePolicy::parse("rr").unwrap(),
                   RoutePolicy::RoundRobin);
        assert_eq!(RoutePolicy::parse("ll").unwrap(),
                   RoutePolicy::LeastLoaded);
        assert!(RoutePolicy::parse("bogus").is_err());
        assert!(RoutePolicy::parse("class-pinned:x").is_err());
        // Reserved partition must leave at least one unreserved replica.
        assert!(RoutePolicy::ClassPinned { reserved: 0 }
            .validate(2)
            .is_err());
        assert!(RoutePolicy::ClassPinned { reserved: 2 }
            .validate(2)
            .is_err());
        assert!(RoutePolicy::ClassPinned { reserved: 1 }
            .validate(2)
            .is_ok());
        assert!(RoutePolicy::LeastLoaded.validate(1).is_ok());
    }

    #[test]
    fn round_robin_rotates_and_skips_draining() {
        let p = RoutePolicy::RoundRobin;
        let mut loads = vec![load(0, 0, 10); 3];
        let c = PriorityClass::Standard;
        assert_eq!(p.order(c, &loads, 0), vec![0, 1, 2]);
        assert_eq!(p.order(c, &loads, 1), vec![1, 2, 0]);
        assert_eq!(p.order(c, &loads, 5), vec![2, 0, 1]);
        loads[1].draining = true;
        assert_eq!(p.order(c, &loads, 1), vec![2, 0], "skips draining");
        loads[0].draining = true;
        loads[2].draining = true;
        assert!(p.order(c, &loads, 0).is_empty(), "whole set draining");
    }

    #[test]
    fn least_loaded_sorts_by_backlog_then_headroom() {
        let p = RoutePolicy::LeastLoaded;
        let loads = vec![
            load(4, 2, 50), // backlog 6
            load(1, 1, 10), // backlog 2, low headroom
            load(2, 0, 99), // backlog 2, high headroom → wins the tie
        ];
        assert_eq!(p.order(PriorityClass::Standard, &loads, 0),
                   vec![2, 1, 0]);
        assert_eq!(p.pick(PriorityClass::Standard, &loads, 0), Some(2));
    }

    #[test]
    fn least_loaded_tie_breaks_on_per_class_sla_headroom() {
        let p = RoutePolicy::LeastLoaded;
        // Equal backlog and KV headroom; replica 1 has more interactive
        // SLA headroom (lower attributed p95), replica 0 more batch
        // headroom — the tie-break is class-directed.
        let mut a = load(1, 1, 10);
        a.class_p95 = [0.080, 0.0, 0.020];
        let mut b = load(1, 1, 10);
        b.class_p95 = [0.030, 0.0, 0.090];
        let loads = vec![a, b];
        assert_eq!(p.pick(PriorityClass::Interactive, &loads, 0), Some(1),
                   "interactive routes to the low-p95 replica");
        assert_eq!(p.pick(PriorityClass::Batch, &loads, 0), Some(0),
                   "batch sees the opposite headroom");
        // No samples for standard on either → falls through to index.
        assert_eq!(p.pick(PriorityClass::Standard, &loads, 0), Some(0));
        // Backlog still dominates the headroom tie-break.
        let mut busy = load(5, 2, 10);
        busy.class_p95 = [0.001, 0.0, 0.0];
        let loads = vec![busy, b];
        assert_eq!(p.pick(PriorityClass::Interactive, &loads, 0), Some(1));
    }

    #[test]
    fn class_pinned_partitions_and_falls_back() {
        let p = RoutePolicy::ClassPinned { reserved: 1 };
        let mut loads = vec![load(5, 0, 10), load(0, 0, 10), load(1, 0, 10)];
        // Interactive sticks to its reserved replica even when busier.
        assert_eq!(p.order(PriorityClass::Interactive, &loads, 0),
                   vec![0, 1, 2]);
        // Other classes avoid the reserved partition.
        assert_eq!(p.order(PriorityClass::Batch, &loads, 0), vec![1, 2, 0]);
        assert_eq!(p.order(PriorityClass::Standard, &loads, 0),
                   vec![1, 2, 0]);
        // Fallback: reserved replica draining → interactive goes to the
        // unreserved partition rather than nowhere.
        loads[0].draining = true;
        assert_eq!(p.order(PriorityClass::Interactive, &loads, 0),
                   vec![1, 2]);
    }

    #[test]
    fn capability_routes_by_profile_and_prompt_len() {
        let p = RoutePolicy::Capability { long_prompt: 512 };
        assert_eq!(RoutePolicy::parse("capability").unwrap(), p);
        assert_eq!(RoutePolicy::parse(&p.label()).unwrap(), p);
        assert_eq!(RoutePolicy::parse("cap").unwrap(), p);
        assert!(RoutePolicy::Capability { long_prompt: 0 }
            .validate(2)
            .is_err());
        // Replica 0: fast decoder, small KV. Replica 1: slow decoder,
        // big KV. Replica 2: baseline, but idle (others have backlog 2).
        let mut fast = load(1, 1, 10);
        fast.decode_speed = 1.5;
        fast.kv_total_blocks = 100;
        let mut big = load(1, 1, 10);
        big.decode_speed = 0.9;
        big.kv_total_blocks = 400;
        let mut idle = load(0, 0, 10);
        idle.kv_total_blocks = 100;
        let loads = vec![fast, big, idle];
        // Interactive chases decode speed even over the idle replica.
        let key = RouteKey::new(PriorityClass::Interactive, 8);
        assert_eq!(p.order(key, &loads, 0), vec![0, 2, 1]);
        // A long batch prompt chases KV pool size.
        let long = RouteKey::new(PriorityClass::Batch, 2048);
        assert_eq!(p.order(long, &loads, 0), vec![1, 2, 0]);
        // Short non-interactive work falls back to least-loaded.
        let short = RouteKey::new(PriorityClass::Batch, 8);
        assert_eq!(p.pick(short, &loads, 0), Some(2));
        // Draining replicas stay excluded.
        let mut l2 = loads.clone();
        l2[0].draining = true;
        assert_eq!(p.order(key, &l2, 0), vec![2, 1]);
        // Homogeneous profiles degrade to least-loaded order.
        let homo = vec![load(2, 0, 10), load(0, 0, 10)];
        assert_eq!(p.order(key, &homo, 0),
                   RoutePolicy::LeastLoaded.order(key, &homo, 0));
    }

    #[test]
    fn rolling_restart_surfaces_dead_replica_as_typed_error() {
        use crate::config::presets::{cpu_host, tiny_real};
        let set = ReplicaSet::build(3, RoutePolicy::RoundRobin, |_| {
            ServiceBuilder::new(tiny_real(), cpu_host())
                .eta_tokens(100_000)
        })
        .unwrap();
        // Kill replica 1's worker; the rotation must refuse it by name
        // instead of hanging on its drain or aborting anonymously.
        set.replica(1).shutdown();
        let err = set.rolling_restart(None).unwrap_err();
        let rolling = err
            .downcast_ref::<RollingError>()
            .expect("rolling restart error must downcast");
        assert_eq!(*rolling, RollingError::Dead { replica: 1 });
        assert_eq!(rolling.replica(), 1);
        assert!(err.to_string().contains("replica 1"), "{err}");
        // Replica 0 was rotated before the failure and must serve.
        assert!(!set.replica(0).is_draining());
        set.shutdown();
    }

    #[test]
    fn drain_reopen_drain_single_replica_reentrancy() {
        use crate::config::presets::{cpu_host, tiny_real};
        let set = ReplicaSet::build(2, RoutePolicy::LeastLoaded, |_| {
            ServiceBuilder::new(tiny_real(), cpu_host())
                .eta_tokens(100_000)
        })
        .unwrap();
        // Regression: drain → reopen → drain on one replica must
        // resolve every time (the drain waiter plumbing re-arms), and
        // the set keeps serving throughout via the other replica.
        for round in 0..2 {
            set.drain_replica(0).unwrap();
            assert!(set.replica(0).is_draining(), "round {round}");
            let (i, h) = set
                .submit_routed(GenRequest::from_text("during", 1))
                .unwrap();
            assert_eq!(i, 1, "round {round}: routed around the drain");
            assert_eq!(h.wait().unwrap().n_tokens, 1);
            set.reopen_replica(0).unwrap();
            assert!(!set.replica(0).is_draining(), "round {round}");
            let h = set.replica(0)
                .submit(GenRequest::from_text("after", 1))
                .unwrap();
            assert_eq!(h.wait().unwrap().n_tokens, 1);
        }
        set.shutdown();
    }

    #[test]
    fn aggregate_folds_counters_and_labels() {
        let mk = |controller: &str, draining: bool| ServiceSnapshot {
            running: 2,
            waiting: 3,
            waiting_by_class: [1, 2, 0],
            resuming: 1,
            kv_used_tokens: 100,
            kv_free_blocks: 5,
            kv_total_blocks: 10,
            kv_shared_tokens: if draining { 64 } else { 128 },
            prefix_hit_rate: if draining { 0.25 } else { 0.75 },
            prefill_padded_tokens: if draining { 40 } else { 60 },
            padding_waste: if draining { 0.3 } else { 0.1 },
            b_t: 8,
            controller: controller.to_string(),
            steps: 7,
            finished: 4,
            rejected: 1,
            shed: 1,
            cancelled: 1,
            reconfigs: 1,
            draining,
            class_lat_p50: [0.01, 0.0, 0.0],
            class_lat_p95: if draining {
                [0.05, 0.0, 0.2]
            } else {
                [0.08, 0.0, 0.1]
            },
            class_ttft_p95: if draining {
                [0.30, 0.0, 0.0]
            } else {
                [0.10, 0.0, 0.0]
            },
            profile: if draining { "big-kv" } else { "baseline" }.into(),
            decode_speed: if draining { 0.9 } else { 1.0 },
            cost_unit: if draining { 1.4 } else { 1.0 },
        };
        let a = ReplicaSet::aggregate(&[mk("x", true), mk("x", false)]);
        assert_eq!(a.running, 4);
        assert_eq!(a.waiting, 6);
        assert_eq!(a.waiting_by_class, [2, 4, 0]);
        assert_eq!(a.class_lat_p95, [0.08, 0.0, 0.2],
                   "set-level per-class p95 is the worst replica");
        assert_eq!(a.class_ttft_p95, [0.30, 0.0, 0.0],
                   "set-level per-class TTFT p95 is the worst replica");
        assert_eq!(a.kv_total_blocks, 20);
        assert_eq!(a.kv_shared_tokens, 192, "shared tokens sum");
        assert_eq!(a.prefix_hit_rate, 0.25,
                   "set hit rate is the coldest replica's");
        assert_eq!(a.prefill_padded_tokens, 100, "padded tokens sum");
        assert_eq!(a.padding_waste, 0.3,
                   "set waste is the worst replica's");
        assert_eq!(a.b_t, 16);
        assert_eq!(a.finished, 8);
        assert_eq!(a.controller, "x", "common label collapses");
        assert_eq!(a.profile, "big-kv|baseline",
                   "distinct profiles join");
        assert!((a.cost_unit - 2.4).abs() < 1e-12,
                "fleet cost rate sums the profiles");
        assert_eq!(a.decode_speed, 1.0, "fastest replica");
        assert!(!a.draining, "one live replica keeps the set serving");
        let b = ReplicaSet::aggregate(&[mk("x", true), mk("y", true)]);
        assert_eq!(b.controller, "x|y");
        assert!(b.draining, "every replica draining → set draining");
    }

    #[test]
    fn burst_spreads_without_waiting_for_snapshots() {
        use crate::config::presets::{cpu_host, tiny_real};
        // Paused replicas: snapshots may lag arbitrarily, yet the
        // routed-count credit keeps per-replica backlog exact
        // (snapshot backlog + in_flight_to ≡ requests routed), so a
        // back-to-back burst alternates instead of herding.
        let set = ReplicaSet::build(2, RoutePolicy::LeastLoaded, |_| {
            ServiceBuilder::new(tiny_real(), cpu_host())
                .eta_tokens(100_000)
                .paused(true)
        })
        .unwrap();
        let mut per = [0usize; 2];
        for _ in 0..6 {
            let (i, _h) = set
                .submit_routed(GenRequest::from_text("burst", 1))
                .unwrap();
            per[i] += 1;
        }
        assert_eq!(per, [3, 3], "in-flight credit must spread the burst");
        set.shutdown();
    }

    #[test]
    fn partition_tuning_reconfigures_each_partition() {
        use crate::config::presets::{cpu_host, tiny_real};
        let set = ReplicaSet::build(
            3,
            RoutePolicy::ClassPinned { reserved: 1 },
            |_| {
                ServiceBuilder::new(tiny_real(), cpu_host())
                    .eta_tokens(100_000)
            },
        )
        .unwrap();
        let (hot, bulk) = set
            .reconfigure_partitions(
                PolicyKind::PerClassSla([Some(0.05), None, None]),
                PolicyKind::MemoryAware,
            )
            .unwrap();
        assert_eq!(hot, "per-class-sla(interactive=50)");
        assert_eq!(bulk, "memory-aware(alg1-linear)");
        // Snapshots republish once per loop iteration; poll for the
        // labels to land.
        let controller_is = |i: usize, want: &str| {
            let deadline = std::time::Instant::now()
                + std::time::Duration::from_secs(5);
            loop {
                let got = set.replica(i).snapshot().controller;
                if got == want {
                    return;
                }
                assert!(std::time::Instant::now() < deadline,
                        "replica {i} stuck on '{got}', want '{want}'");
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        };
        controller_is(0, &hot);
        controller_is(1, &bulk);
        controller_is(2, &bulk);
        // Single-replica override also works…
        let l = set
            .reconfigure_replica(2, PolicyKind::StaticFixed { batch: 4 })
            .unwrap();
        assert_eq!(l, "static-fixed:4");
        controller_is(2, "static-fixed:4");
        controller_is(1, &bulk);
        // …and partition tuning refuses without class-pinned routing.
        let rr = ReplicaSet::build(2, RoutePolicy::RoundRobin, |_| {
            ServiceBuilder::new(tiny_real(), cpu_host())
                .eta_tokens(100_000)
        })
        .unwrap();
        assert!(rr
            .reconfigure_partitions(
                PolicyKind::MemoryAware,
                PolicyKind::MemoryAware,
            )
            .is_err());
        set.shutdown();
        rr.shutdown();
    }

    #[test]
    fn health_tracker_straggler_detection_with_hysteresis() {
        let pol = HealthPolicy {
            suspect_factor: 3.0,
            suspect_dwell: 2,
            recover_dwell: 2,
        };
        let mut t = HealthTracker::new(3, pol);
        assert!(t.states().iter().all(|h| *h == Health::Healthy));
        // Replica 2 straggles at 10× the fleet median (0.02).
        let slow = [0.02, 0.02, 0.20];
        assert!(t.observe(&slow).is_empty(), "one sample is not enough");
        assert_eq!(t.state(2), Health::Healthy);
        assert_eq!(t.observe(&slow), vec![2], "dwell reached");
        assert_eq!(t.state(2), Health::Suspect);
        assert!(!t.routable(2));
        assert!(t.observe(&slow).is_empty(), "already suspect");
        // Clean observations promote it back after the recover dwell.
        let clean = [0.02, 0.02, 0.025];
        assert!(t.observe(&clean).is_empty());
        assert_eq!(t.state(2), Health::Suspect, "hysteresis holds");
        t.observe(&clean);
        assert_eq!(t.state(2), Health::Healthy);
        // A noisy single straggle resets the clean streak but does not
        // condemn: slow, clean, slow never reaches the dwell.
        for obs in [&slow, &clean, &slow, &clean] {
            t.observe(obs);
        }
        assert_eq!(t.state(2), Health::Healthy);
        // Hard failure: down → not routable, observe skips it, explicit
        // recovery puts it on probation, clean dwell promotes.
        t.mark_down(2);
        assert_eq!(t.state(2), Health::Down);
        assert!(!t.routable(2));
        t.observe(&clean);
        assert_eq!(t.state(2), Health::Down, "observe never resurrects");
        t.mark_recovering(2);
        assert_eq!(t.state(2), Health::Recovering);
        assert!(t.routable(2), "probation is routable");
        t.observe(&clean);
        t.observe(&clean);
        assert_eq!(t.state(2), Health::Healthy);
        // mark_recovering is a no-op off the Down state.
        t.mark_recovering(2);
        assert_eq!(t.state(2), Health::Healthy);
        // With two replicas the median is the healthy one (lower
        // median), so the straggler is still detected.
        let mut t2 = HealthTracker::new(2, pol);
        let s2 = [0.02, 0.30];
        t2.observe(&s2);
        assert_eq!(t2.observe(&s2), vec![1]);
    }

    #[test]
    fn routing_excludes_unhealthy_replicas() {
        let mut loads = vec![load(0, 0, 10); 3];
        loads[0].health = Health::Suspect;
        let c = PriorityClass::Standard;
        assert_eq!(RoutePolicy::RoundRobin.order(c, &loads, 0), vec![1, 2]);
        assert_eq!(RoutePolicy::LeastLoaded.order(c, &loads, 0),
                   vec![1, 2]);
        loads[1].health = Health::Down;
        assert_eq!(RoutePolicy::LeastLoaded.order(c, &loads, 0), vec![2]);
        loads[1].health = Health::Recovering;
        assert_eq!(RoutePolicy::LeastLoaded.order(c, &loads, 0),
                   vec![1, 2], "recovering replicas serve again");
        // Class-pinned: a fully-down reserved partition spills
        // interactive traffic across partitions instead of rejecting.
        let p = RoutePolicy::ClassPinned { reserved: 1 };
        let mut pin = vec![load(0, 0, 10); 3];
        pin[0].health = Health::Down;
        assert_eq!(p.order(PriorityClass::Interactive, &pin, 0),
                   vec![1, 2]);
    }

    #[test]
    fn mark_down_routes_around_and_recovery_restores() {
        use crate::config::presets::{cpu_host, tiny_real};
        let set = ReplicaSet::build(2, RoutePolicy::RoundRobin, |_| {
            ServiceBuilder::new(tiny_real(), cpu_host())
                .eta_tokens(100_000)
        })
        .unwrap();
        set.mark_down(0).unwrap();
        assert_eq!(set.health_states(), vec![Health::Down,
                                             Health::Healthy]);
        for _ in 0..4 {
            let (i, h) = set
                .submit_routed(GenRequest::from_text("hi", 1))
                .unwrap();
            assert_eq!(i, 1, "down replica must not receive traffic");
            assert_eq!(h.wait().unwrap().n_tokens, 1);
        }
        set.mark_recovering(0).unwrap();
        assert_eq!(set.health_states()[0], Health::Recovering);
        let mut hit0 = false;
        for _ in 0..4 {
            let (i, h) = set
                .submit_routed(GenRequest::from_text("hi", 1))
                .unwrap();
            hit0 |= i == 0;
            assert_eq!(h.wait().unwrap().n_tokens, 1);
        }
        assert!(hit0, "recovering replica serves again");
        assert!(set.mark_down(9).is_err(), "out-of-range is typed");
        // Degraded mode: every replica unhealthy → health-blind
        // routing still serves rather than rejecting.
        set.mark_down(0).unwrap();
        set.mark_down(1).unwrap();
        let (_, h) = set
            .submit_routed(GenRequest::from_text("degraded", 1))
            .unwrap();
        assert_eq!(h.wait().unwrap().n_tokens, 1);
        set.shutdown();
    }

    #[test]
    fn submit_survives_replica_death_with_typed_fall_through() {
        use crate::config::presets::{cpu_host, tiny_real};
        let set = ReplicaSet::build(2, RoutePolicy::RoundRobin, |_| {
            ServiceBuilder::new(tiny_real(), cpu_host())
                .eta_tokens(100_000)
        })
        .unwrap();
        // Regression (chaos PR): a dead replica's submit refusal must
        // be a downcastable SubmitError so the router falls through to
        // the next candidate instead of surfacing the first replica's
        // error. Kill replica 0 mid-burst; every routed submit must
        // still land.
        let dead = Arc::clone(&set.replicas[0]);
        let killer = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(5));
            dead.shutdown();
        });
        for k in 0..50 {
            let (_, h) = set
                .submit_routed(GenRequest::from_text("race", 1))
                .unwrap_or_else(|e| {
                    panic!("submit {k} must fall through, got: {e:#}")
                });
            assert_eq!(h.wait().unwrap().n_tokens, 1);
        }
        killer.join().unwrap();
        // The dead replica's direct refusal is typed…
        let err =
            set.replicas[0].submit(GenRequest::from_text("x", 1));
        assert!(matches!(
            err.unwrap_err().downcast_ref::<SubmitError>(),
            Some(SubmitError::ShutDown)
        ));
        // …and routed submissions keep landing on the survivor.
        let (i, h) = set
            .submit_routed(GenRequest::from_text("after", 1))
            .unwrap();
        assert_eq!(i, 1);
        assert_eq!(h.wait().unwrap().n_tokens, 1);
        set.shutdown();
    }

    #[test]
    fn replica_of_inverts_the_id_namespace() {
        use crate::config::presets::{cpu_host, tiny_real};
        let set = ReplicaSet::build(3, RoutePolicy::RoundRobin, |_| {
            ServiceBuilder::new(tiny_real(), cpu_host())
                .eta_tokens(100_000)
                .paused(true)
        })
        .unwrap();
        for k in 0..6 {
            let (i, h) = set
                .submit_routed(GenRequest::from_text("ns", 1))
                .unwrap();
            assert_eq!(i, k % 3, "round-robin order");
            assert_eq!(set.replica_of(h.id()), i,
                       "id {} must map back to replica {i}", h.id());
        }
        set.shutdown();
    }
}
