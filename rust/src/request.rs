//! Request lifecycle: the unit of work flowing router → queue → scheduler
//! → engine, with the timestamps the metrics layer needs (TTFT, TBT, SLA
//! attainment), plus the typed submission metadata the service layer
//! carries (priority class, sampling parameters, deadline).

use anyhow::{bail, Result};

pub type RequestId = u64;

/// Service priority class. Admission is class-weighted (smooth weighted
/// round-robin over the per-class waiting queues), so higher classes win
/// contended `b_t` slots without starving lower ones.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum PriorityClass {
    /// Latency-sensitive, user-facing traffic.
    Interactive,
    /// Default class.
    #[default]
    Standard,
    /// Throughput-oriented background work (e.g. eval or RLHF sampling).
    Batch,
}

impl PriorityClass {
    pub const COUNT: usize = 3;
    pub const ALL: [PriorityClass; Self::COUNT] =
        [PriorityClass::Interactive, PriorityClass::Standard,
         PriorityClass::Batch];

    /// Queue index: 0 = highest priority.
    pub fn rank(self) -> usize {
        match self {
            PriorityClass::Interactive => 0,
            PriorityClass::Standard => 1,
            PriorityClass::Batch => 2,
        }
    }

    /// Admission weight for the weighted round-robin (per contended slot,
    /// Interactive gets ~8/12, Standard ~3/12, Batch ~1/12 of admissions
    /// when every class is backlogged).
    pub fn weight(self) -> u32 {
        match self {
            PriorityClass::Interactive => 8,
            PriorityClass::Standard => 3,
            PriorityClass::Batch => 1,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            PriorityClass::Interactive => "interactive",
            PriorityClass::Standard => "standard",
            PriorityClass::Batch => "batch",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.trim() {
            "interactive" | "high" => PriorityClass::Interactive,
            "standard" | "normal" | "" => PriorityClass::Standard,
            "batch" | "low" => PriorityClass::Batch,
            other => bail!("unknown priority class '{other}' \
                            (want interactive|standard|batch)"),
        })
    }
}

/// Why a request reached [`Phase::Finished`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// Generated its full budget.
    Completed,
    /// Rejected at admission: prompt + budget exceeds the engine's
    /// maximum sequence length.
    Rejected,
    /// Shed from the waiting queue after its deadline expired.
    DeadlineExceeded,
    /// Cancelled by the client; any KV blocks were freed mid-flight.
    Cancelled,
    /// The replica serving this request died after streaming had begun;
    /// the partial output cannot be transparently re-derived, so the
    /// request fails with a typed error instead of hanging.
    Failed,
}

/// Typed sampling parameters, carried end-to-end (service → wire →
/// scheduler → engine). Current engines decode greedily; the parameters
/// are validated, transported and recorded so engines that sample can
/// honour them without another protocol change — see DESIGN.md.
#[derive(Debug, Clone, PartialEq)]
pub struct SamplingParams {
    /// 0.0 = greedy (the default).
    pub temperature: f64,
    /// 0 = disabled.
    pub top_k: u32,
    /// Nucleus mass in (0, 1]; 1.0 = disabled.
    pub top_p: f64,
    /// Per-request sampling seed.
    pub seed: Option<u64>,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams { temperature: 0.0, top_k: 0, top_p: 1.0, seed: None }
    }
}

impl SamplingParams {
    pub fn greedy() -> Self {
        Self::default()
    }

    pub fn validate(&self) -> Result<()> {
        if !self.temperature.is_finite() || self.temperature < 0.0 {
            bail!("sampling.temperature must be finite and >= 0");
        }
        if !self.top_p.is_finite() || self.top_p <= 0.0 || self.top_p > 1.0 {
            bail!("sampling.top_p must be in (0, 1]");
        }
        Ok(())
    }
}

/// Where a request currently is in its lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// In the waiting queue; no KV allocated.
    Waiting,
    /// Admitted; prompt (or a prefix of it) is being prefilled.
    Prefill,
    /// Generating tokens.
    Decode,
    /// Victim of a memory-pressure preemption, waiting to resume.
    Preempted,
    /// Done (all tokens generated or aborted).
    Finished,
}

#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    /// Prompt length in tokens (the actual token ids live engine-side; the
    /// scheduler only needs counts).
    pub prompt_len: u32,
    /// Generation budget: the request finishes after this many new tokens.
    pub max_new_tokens: u32,
    /// Arrival time (scheduler clock, seconds).
    pub arrived_at: f64,

    // ---- mutable progress ----
    pub phase: Phase,
    /// Prompt tokens prefilled so far (chunked prefill advances this).
    pub prefilled: u32,
    /// Tokens generated so far.
    pub generated: u32,
    /// First-token emission time.
    pub first_token_at: Option<f64>,
    /// Completion time.
    pub finished_at: Option<f64>,
    /// Number of times this request was preempted.
    pub preemptions: u32,
    /// Engine slot while running (PJRT engine bookkeeping).
    pub slot: Option<usize>,
    /// Raw prompt token ids (real-engine path only; empty in simulation).
    pub prompt_tokens: Vec<i32>,
    /// Generated token ids (real-engine path only).
    pub output_tokens: Vec<i32>,

    // ---- service metadata ----
    /// Priority class for class-weighted admission.
    pub class: PriorityClass,
    /// Absolute scheduler-clock deadline (seconds) for the first token;
    /// still-waiting requests are shed once it passes. None = no deadline.
    pub deadline: Option<f64>,
    /// Sampling parameters (plumbed through; engines decode greedily).
    pub sampling: SamplingParams,
    /// Set when the request reaches [`Phase::Finished`].
    pub finish: Option<FinishReason>,
}

impl Request {
    pub fn new(id: RequestId, prompt_len: u32, max_new_tokens: u32,
               arrived_at: f64) -> Self {
        Request {
            id,
            prompt_len,
            max_new_tokens,
            arrived_at,
            phase: Phase::Waiting,
            prefilled: 0,
            generated: 0,
            first_token_at: None,
            finished_at: None,
            preemptions: 0,
            slot: None,
            prompt_tokens: Vec::new(),
            output_tokens: Vec::new(),
            class: PriorityClass::default(),
            deadline: None,
            sampling: SamplingParams::default(),
            finish: None,
        }
    }

    pub fn with_class(mut self, class: PriorityClass) -> Self {
        self.class = class;
        self
    }

    pub fn with_deadline(mut self, deadline: Option<f64>) -> Self {
        self.deadline = deadline;
        self
    }

    pub fn with_sampling(mut self, sampling: SamplingParams) -> Self {
        self.sampling = sampling;
        self
    }

    pub fn with_tokens(id: RequestId, prompt_tokens: Vec<i32>,
                       max_new_tokens: u32, arrived_at: f64) -> Self {
        let mut r = Self::new(id, prompt_tokens.len() as u32, max_new_tokens,
                              arrived_at);
        r.prompt_tokens = prompt_tokens;
        r
    }

    /// Tokens currently resident in the KV cache for this request.
    pub fn cached_tokens(&self) -> u32 {
        match self.phase {
            Phase::Waiting | Phase::Preempted | Phase::Finished => 0,
            _ => self.prefilled + self.generated,
        }
    }

    /// Total tokens this request will eventually occupy (the scheduler's
    /// worst-case growth bound).
    pub fn final_tokens(&self) -> u32 {
        self.prompt_len + self.max_new_tokens
    }

    pub fn prefill_done(&self) -> bool {
        self.prefilled >= self.prompt_len
    }

    pub fn decode_done(&self) -> bool {
        self.generated >= self.max_new_tokens
    }

    pub fn is_running(&self) -> bool {
        matches!(self.phase, Phase::Prefill | Phase::Decode)
    }

    /// Record one generated token at time `now`; returns true if finished.
    pub fn record_token(&mut self, now: f64) -> bool {
        debug_assert!(self.phase == Phase::Decode || self.prefill_done());
        self.generated += 1;
        if self.first_token_at.is_none() {
            self.first_token_at = Some(now);
        }
        if self.decode_done() {
            self.phase = Phase::Finished;
            self.finished_at = Some(now);
            self.finish = Some(FinishReason::Completed);
            true
        } else {
            false
        }
    }

    /// Terminate without completing (reject / shed / cancel).
    pub fn terminate(&mut self, reason: FinishReason, now: f64) {
        self.phase = Phase::Finished;
        self.finished_at = Some(now);
        self.finish = Some(reason);
        self.slot = None;
    }

    /// Reset to re-run from scratch after a recompute-preemption (vLLM
    /// semantics: generated tokens are re-derived greedily, so progress
    /// counts are kept but the cache must be rebuilt; the prompt AND the
    /// already-generated tokens are re-prefilled on resume).
    pub fn preempt_recompute(&mut self) {
        debug_assert!(self.is_running());
        self.preemptions += 1;
        self.phase = Phase::Preempted;
        // All prefill progress is lost; generated tokens stay (they will be
        // re-prefilled as part of the restored context).
        self.prefilled = 0;
        self.slot = None;
    }

    /// Tokens that must be prefilled when resuming after recompute:
    /// prompt + already-generated context.
    pub fn resume_prefill_tokens(&self) -> u32 {
        self.prompt_len + self.generated
    }

    // ---- metrics ----

    pub fn ttft(&self) -> Option<f64> {
        self.first_token_at.map(|t| t - self.arrived_at)
    }

    pub fn e2e_latency(&self) -> Option<f64> {
        self.finished_at.map(|t| t - self.arrived_at)
    }

    /// Mean time between tokens over the decode phase.
    pub fn mean_tbt(&self) -> Option<f64> {
        match (self.first_token_at, self.finished_at) {
            (Some(f), Some(d)) if self.generated > 1 => {
                Some((d - f) / (self.generated - 1) as f64)
            }
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_happy_path() {
        let mut r = Request::new(1, 10, 3, 0.0);
        assert_eq!(r.phase, Phase::Waiting);
        assert_eq!(r.cached_tokens(), 0);
        assert_eq!(r.final_tokens(), 13);

        r.phase = Phase::Prefill;
        r.prefilled = 10;
        assert!(r.prefill_done());
        r.phase = Phase::Decode;
        assert_eq!(r.cached_tokens(), 10);

        assert!(!r.record_token(1.0));
        assert!(!r.record_token(1.1));
        assert!(r.record_token(1.2));
        assert_eq!(r.phase, Phase::Finished);
        assert_eq!(r.ttft(), Some(1.0));
        assert_eq!(r.e2e_latency(), Some(1.2));
        let tbt = r.mean_tbt().unwrap();
        assert!((tbt - 0.1).abs() < 1e-9, "tbt={tbt}");
    }

    #[test]
    fn chunked_prefill_progress() {
        let mut r = Request::new(2, 100, 5, 0.0);
        r.phase = Phase::Prefill;
        r.prefilled = 64;
        assert!(!r.prefill_done());
        assert_eq!(r.cached_tokens(), 64);
        r.prefilled = 100;
        assert!(r.prefill_done());
    }

    #[test]
    fn recompute_preemption_resets_cache_keeps_progress() {
        let mut r = Request::new(3, 20, 10, 0.0);
        r.phase = Phase::Prefill;
        r.prefilled = 20;
        r.phase = Phase::Decode;
        r.record_token(0.5);
        r.record_token(0.6);
        r.preempt_recompute();
        assert_eq!(r.phase, Phase::Preempted);
        assert_eq!(r.generated, 2);
        assert_eq!(r.prefilled, 0);
        assert_eq!(r.cached_tokens(), 0);
        assert_eq!(r.resume_prefill_tokens(), 22);
        assert_eq!(r.preemptions, 1);
        // TTFT survives preemption (first token already emitted).
        assert_eq!(r.ttft(), Some(0.5));
    }

    #[test]
    fn single_token_request_has_no_tbt() {
        let mut r = Request::new(4, 5, 1, 0.0);
        r.phase = Phase::Decode;
        r.prefilled = 5;
        assert!(r.record_token(2.0));
        assert_eq!(r.mean_tbt(), None);
        assert_eq!(r.e2e_latency(), Some(2.0));
    }

    #[test]
    fn with_tokens_sets_len() {
        let r = Request::with_tokens(5, vec![1, 2, 3], 4, 0.0);
        assert_eq!(r.prompt_len, 3);
        assert_eq!(r.prompt_tokens, vec![1, 2, 3]);
    }

    #[test]
    fn class_defaults_and_builders() {
        let r = Request::new(6, 10, 2, 0.0);
        assert_eq!(r.class, PriorityClass::Standard);
        assert_eq!(r.deadline, None);
        assert_eq!(r.sampling, SamplingParams::greedy());
        let r = r
            .with_class(PriorityClass::Interactive)
            .with_deadline(Some(1.5));
        assert_eq!(r.class, PriorityClass::Interactive);
        assert_eq!(r.deadline, Some(1.5));
    }

    #[test]
    fn priority_class_parse_label_roundtrip() {
        for c in PriorityClass::ALL {
            assert_eq!(PriorityClass::parse(c.label()).unwrap(), c);
        }
        assert_eq!(PriorityClass::parse("high").unwrap(),
                   PriorityClass::Interactive);
        assert!(PriorityClass::parse("vip").is_err());
        // Ranks are dense and weights strictly ordered.
        let ranks: Vec<usize> =
            PriorityClass::ALL.iter().map(|c| c.rank()).collect();
        assert_eq!(ranks, vec![0, 1, 2]);
        assert!(PriorityClass::Interactive.weight()
                > PriorityClass::Standard.weight());
        assert!(PriorityClass::Standard.weight()
                > PriorityClass::Batch.weight());
    }

    #[test]
    fn sampling_validation() {
        assert!(SamplingParams::greedy().validate().is_ok());
        let bad = SamplingParams { temperature: -1.0, ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = SamplingParams { top_p: 0.0, ..Default::default() };
        assert!(bad.validate().is_err());
        let ok = SamplingParams {
            temperature: 0.7,
            top_k: 40,
            top_p: 0.9,
            seed: Some(7),
        };
        assert!(ok.validate().is_ok());
    }

    #[test]
    fn finish_reasons_recorded() {
        let mut r = Request::new(7, 4, 1, 0.0);
        assert_eq!(r.finish, None);
        r.phase = Phase::Decode;
        r.prefilled = 4;
        r.record_token(1.0);
        assert_eq!(r.finish, Some(FinishReason::Completed));

        let mut r = Request::new(8, 4, 1, 0.0);
        r.terminate(FinishReason::Cancelled, 2.0);
        assert_eq!(r.phase, Phase::Finished);
        assert_eq!(r.finish, Some(FinishReason::Cancelled));
        assert_eq!(r.finished_at, Some(2.0));
    }
}
