//! Paged KV-cache block manager (the vLLM-style memory substrate).
//!
//! GPU KV memory is divided into fixed-size blocks of `block_tokens`
//! tokens. Each running request owns a block table; blocks move between
//! the GPU free pool, request tables, and an (optional) CPU swap pool.
//! The manager is purely accounting — actual tensor storage lives in the
//! engine — but its numbers *are* the memory constraint `M(b_t) ≤ M_max`
//! the paper's Algorithm 1 manages, so its invariants are property-tested
//! hard (no leaks, no double-free, exact token↔block arithmetic).
//!
//! ## Data layout (hot-path overhaul)
//!
//! Block tables live in a slab: a dense `Vec<Option<Allocation>>` plus a
//! free-list, with a `RequestId → slot` map consulted only at the
//! admission boundary. The scheduler caches each running request's
//! [`KvSlot`] and drives the per-step path through the `*_at` methods,
//! so decode-growth checks are a single array index. Aggregates the
//! telemetry reads every step — [`KvBlockManager::used_tokens`],
//! [`KvBlockManager::resident_requests`] — are maintained incrementally
//! on every allocate/grow/free/swap and are O(1) reads; they used to be
//! full `BTreeMap` walks, twice per scheduler step.
//! [`KvBlockManager::check_invariants`] still recomputes everything from
//! scratch and cross-checks the cached counters.

use crate::request::RequestId;
use std::collections::HashMap;

/// Dense slab handle for a live block table. Valid from `allocate` until
/// `free`; the owner (the scheduler) must drop it at free time. Survives
/// swap-out/swap-in (the allocation record stays in place).
pub type KvSlot = u32;

/// Sentinel for "no KV slot cached".
pub const KV_NO_SLOT: KvSlot = u32::MAX;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum KvError {
    OutOfBlocks { needed: usize, free: usize },
    UnknownRequest(RequestId),
    AlreadyAllocated(RequestId),
    SwapSpaceExhausted { needed: usize, free: usize },
}

impl std::fmt::Display for KvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KvError::OutOfBlocks { needed, free } => {
                write!(f, "out of KV blocks: need {needed}, free {free}")
            }
            KvError::UnknownRequest(id) => write!(f, "unknown request {id}"),
            KvError::AlreadyAllocated(id) => {
                write!(f, "request {id} already has a block table")
            }
            KvError::SwapSpaceExhausted { needed, free } => {
                write!(f, "swap space exhausted: need {needed}, free {free}")
            }
        }
    }
}

impl std::error::Error for KvError {}

#[derive(Debug, Clone)]
struct Allocation {
    id: RequestId,
    blocks: usize,
    tokens: u32,
    swapped: bool,
}

/// Block-granular KV accounting for one device (or TP group).
#[derive(Debug, Clone)]
pub struct KvBlockManager {
    block_tokens: u32,
    total_blocks: usize,
    free_blocks: usize,
    /// CPU swap pool capacity in blocks (0 disables swapping).
    swap_blocks_total: usize,
    swap_blocks_free: usize,
    /// Slab of live block tables + free-list of vacated slots.
    slots: Vec<Option<Allocation>>,
    free_slots: Vec<KvSlot>,
    /// Admission-boundary index; the per-step path uses [`KvSlot`]s.
    by_id: HashMap<RequestId, KvSlot>,
    /// Cached Σ tokens of on-device (non-swapped) tables — O(1) reads.
    used_tokens_device: u64,
    /// Cached count of on-device (non-swapped) tables — O(1) reads.
    resident: usize,
    /// Cumulative counters for telemetry.
    pub stat_allocs: u64,
    pub stat_frees: u64,
    pub stat_swap_outs: u64,
    pub stat_swap_ins: u64,
}

impl KvBlockManager {
    /// `capacity_tokens` is η — the token budget the hardware's KV memory
    /// allows (HardwareSpec::kv_budget / kv_bytes_per_token).
    pub fn new(capacity_tokens: u64, block_tokens: u32,
               swap_capacity_tokens: u64) -> Self {
        assert!(block_tokens > 0);
        let total_blocks = (capacity_tokens / block_tokens as u64) as usize;
        let swap_blocks = (swap_capacity_tokens / block_tokens as u64) as usize;
        KvBlockManager {
            block_tokens,
            total_blocks,
            free_blocks: total_blocks,
            swap_blocks_total: swap_blocks,
            swap_blocks_free: swap_blocks,
            slots: Vec::new(),
            free_slots: Vec::new(),
            by_id: HashMap::new(),
            used_tokens_device: 0,
            resident: 0,
            stat_allocs: 0,
            stat_frees: 0,
            stat_swap_outs: 0,
            stat_swap_ins: 0,
        }
    }

    pub fn block_tokens(&self) -> u32 {
        self.block_tokens
    }

    pub fn total_blocks(&self) -> usize {
        self.total_blocks
    }

    pub fn free_blocks(&self) -> usize {
        self.free_blocks
    }

    pub fn used_blocks(&self) -> usize {
        self.total_blocks - self.free_blocks
    }

    /// Capacity in tokens (η, rounded down to block granularity).
    pub fn capacity_tokens(&self) -> u64 {
        self.total_blocks as u64 * self.block_tokens as u64
    }

    /// Tokens currently resident on device. O(1): maintained
    /// incrementally, cross-checked by [`Self::check_invariants`].
    pub fn used_tokens(&self) -> u64 {
        self.used_tokens_device
    }

    /// Live on-device (non-swapped) block tables. O(1).
    pub fn resident_requests(&self) -> usize {
        self.resident
    }

    pub fn utilization(&self) -> f64 {
        if self.total_blocks == 0 {
            return 1.0;
        }
        self.used_blocks() as f64 / self.total_blocks as f64
    }

    fn blocks_for(&self, tokens: u32) -> usize {
        tokens.div_ceil(self.block_tokens) as usize
    }

    fn alloc_at(&self, slot: KvSlot) -> &Allocation {
        self.slots[slot as usize].as_ref().expect("live KV slot")
    }

    fn alloc_at_mut(&mut self, slot: KvSlot) -> &mut Allocation {
        self.slots[slot as usize].as_mut().expect("live KV slot")
    }

    /// The slab slot backing `id`'s block table, for the `*_at` fast
    /// path. Cache it at admission; it stays valid until `free`.
    pub fn slot_of(&self, id: RequestId) -> Option<KvSlot> {
        self.by_id.get(&id).copied()
    }

    /// Can `tokens` more tokens be appended for `id` (or allocated fresh)
    /// without exceeding capacity?
    pub fn can_grow(&self, id: RequestId, tokens: u32) -> bool {
        let cur = self
            .by_id
            .get(&id)
            .map(|&s| {
                let a = self.alloc_at(s);
                (a.blocks, a.tokens)
            });
        let (blocks, cur_tokens) = cur.unwrap_or((0, 0));
        let need = self.blocks_for(cur_tokens + tokens) - blocks;
        need <= self.free_blocks
    }

    /// [`Self::can_grow`] over a cached slot: one array index, no map
    /// lookup — the per-decode-token path.
    pub fn can_grow_at(&self, slot: KvSlot, tokens: u32) -> bool {
        let a = self.alloc_at(slot);
        let need = self.blocks_for(a.tokens + tokens) - a.blocks;
        need <= self.free_blocks
    }

    /// Allocate the initial table for a request's first `tokens` tokens.
    pub fn allocate(&mut self, id: RequestId, tokens: u32)
                    -> Result<(), KvError> {
        if self.by_id.contains_key(&id) {
            return Err(KvError::AlreadyAllocated(id));
        }
        let need = self.blocks_for(tokens);
        if need > self.free_blocks {
            return Err(KvError::OutOfBlocks { needed: need,
                                              free: self.free_blocks });
        }
        let alloc =
            Allocation { id, blocks: need, tokens, swapped: false };
        let slot = match self.free_slots.pop() {
            Some(s) => {
                debug_assert!(self.slots[s as usize].is_none());
                self.slots[s as usize] = Some(alloc);
                s
            }
            None => {
                self.slots.push(Some(alloc));
                (self.slots.len() - 1) as KvSlot
            }
        };
        self.by_id.insert(id, slot);
        self.free_blocks -= need;
        self.used_tokens_device += tokens as u64;
        self.resident += 1;
        self.stat_allocs += 1;
        Ok(())
    }

    /// Append `tokens` tokens to an existing table (decode growth or the
    /// next prefill chunk), acquiring new blocks as needed.
    pub fn grow(&mut self, id: RequestId, tokens: u32) -> Result<(), KvError> {
        let slot = *self
            .by_id
            .get(&id)
            .ok_or(KvError::UnknownRequest(id))?;
        self.grow_at(slot, tokens)
    }

    /// [`Self::grow`] over a cached slot (per-step fast path).
    pub fn grow_at(&mut self, slot: KvSlot, tokens: u32)
                   -> Result<(), KvError> {
        let free = self.free_blocks;
        let block_tokens = self.block_tokens;
        let alloc = self.alloc_at_mut(slot);
        debug_assert!(!alloc.swapped, "grow on swapped request");
        let new_tokens = alloc.tokens + tokens;
        let need_total = new_tokens.div_ceil(block_tokens) as usize;
        let extra = need_total.saturating_sub(alloc.blocks);
        if extra > free {
            return Err(KvError::OutOfBlocks { needed: extra, free });
        }
        alloc.blocks = need_total;
        alloc.tokens = new_tokens;
        self.free_blocks -= extra;
        self.used_tokens_device += tokens as u64;
        Ok(())
    }

    /// Release a request's blocks (finish or recompute-preemption).
    pub fn free(&mut self, id: RequestId) -> Result<u32, KvError> {
        let slot = self
            .by_id
            .remove(&id)
            .ok_or(KvError::UnknownRequest(id))?;
        let alloc =
            self.slots[slot as usize].take().expect("indexed KV slot");
        self.free_slots.push(slot);
        if alloc.swapped {
            self.swap_blocks_free += alloc.blocks;
        } else {
            self.free_blocks += alloc.blocks;
            self.used_tokens_device -= alloc.tokens as u64;
            self.resident -= 1;
        }
        self.stat_frees += 1;
        debug_assert!(self.free_blocks <= self.total_blocks);
        Ok(alloc.tokens)
    }

    /// Move a request's blocks to the CPU pool. Returns the bytes-worth of
    /// blocks moved (in tokens) so the engine can cost the transfer.
    pub fn swap_out(&mut self, id: RequestId) -> Result<u32, KvError> {
        let slot = *self
            .by_id
            .get(&id)
            .ok_or(KvError::UnknownRequest(id))?;
        let swap_free = self.swap_blocks_free;
        let alloc = self.alloc_at_mut(slot);
        debug_assert!(!alloc.swapped);
        if alloc.blocks > swap_free {
            return Err(KvError::SwapSpaceExhausted {
                needed: alloc.blocks,
                free: swap_free,
            });
        }
        alloc.swapped = true;
        let (blocks, tokens) = (alloc.blocks, alloc.tokens);
        self.swap_blocks_free -= blocks;
        self.free_blocks += blocks;
        self.used_tokens_device -= tokens as u64;
        self.resident -= 1;
        self.stat_swap_outs += 1;
        Ok(tokens)
    }

    /// Bring a swapped request back to the device.
    pub fn swap_in(&mut self, id: RequestId) -> Result<u32, KvError> {
        let slot = *self
            .by_id
            .get(&id)
            .ok_or(KvError::UnknownRequest(id))?;
        let free = self.free_blocks;
        let alloc = self.alloc_at_mut(slot);
        debug_assert!(alloc.swapped);
        if alloc.blocks > free {
            return Err(KvError::OutOfBlocks { needed: alloc.blocks,
                                              free });
        }
        alloc.swapped = false;
        let (blocks, tokens) = (alloc.blocks, alloc.tokens);
        self.free_blocks -= blocks;
        self.swap_blocks_free += blocks;
        self.used_tokens_device += tokens as u64;
        self.resident += 1;
        self.stat_swap_ins += 1;
        Ok(tokens)
    }

    pub fn is_swapped(&self, id: RequestId) -> bool {
        self.by_id
            .get(&id)
            .map(|&s| self.alloc_at(s).swapped)
            .unwrap_or(false)
    }

    pub fn tokens_of(&self, id: RequestId) -> Option<u32> {
        self.by_id.get(&id).map(|&s| self.alloc_at(s).tokens)
    }

    /// Internal consistency check (used by tests and debug assertions):
    /// free + Σ tables(on-device) == total, same for swap pool, block
    /// arithmetic exact per table, and the O(1) cached aggregates equal
    /// their from-scratch recomputation.
    pub fn check_invariants(&self) -> Result<(), String> {
        let live = || self.slots.iter().flatten();
        let dev: usize =
            live().filter(|a| !a.swapped).map(|a| a.blocks).sum();
        if dev + self.free_blocks != self.total_blocks {
            return Err(format!(
                "device leak: used {dev} + free {} != total {}",
                self.free_blocks, self.total_blocks
            ));
        }
        let swp: usize =
            live().filter(|a| a.swapped).map(|a| a.blocks).sum();
        if swp + self.swap_blocks_free != self.swap_blocks_total {
            return Err(format!(
                "swap leak: used {swp} + free {} != total {}",
                self.swap_blocks_free, self.swap_blocks_total
            ));
        }
        for a in live() {
            let want = a.tokens.div_ceil(self.block_tokens) as usize;
            if a.blocks != want {
                return Err(format!(
                    "req {}: {} tokens in {} blocks (want {want})",
                    a.id, a.tokens, a.blocks
                ));
            }
        }
        // Cached aggregates vs full recomputation.
        let used: u64 = live()
            .filter(|a| !a.swapped)
            .map(|a| a.tokens as u64)
            .sum();
        if used != self.used_tokens_device {
            return Err(format!(
                "used_tokens cache drift: cached {} != recomputed {used}",
                self.used_tokens_device
            ));
        }
        let res = live().filter(|a| !a.swapped).count();
        if res != self.resident {
            return Err(format!(
                "resident cache drift: cached {} != recomputed {res}",
                self.resident
            ));
        }
        // Index ↔ slab coherence.
        let n_live = live().count();
        if n_live != self.by_id.len() {
            return Err(format!(
                "index drift: {} live slots vs {} index entries",
                n_live,
                self.by_id.len()
            ));
        }
        for (&id, &slot) in &self.by_id {
            match self.slots.get(slot as usize).and_then(|s| s.as_ref()) {
                Some(a) if a.id == id => {}
                _ => {
                    return Err(format!(
                        "index drift: request {id} maps to dead slot {slot}"
                    ))
                }
            }
        }
        if self.free_slots.len() + n_live != self.slots.len() {
            return Err(format!(
                "free-list drift: {} free + {} live != {} slots",
                self.free_slots.len(),
                n_live,
                self.slots.len()
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn mgr(tokens: u64) -> KvBlockManager {
        KvBlockManager::new(tokens, 16, tokens)
    }

    #[test]
    fn allocate_grow_free_roundtrip() {
        let mut m = mgr(1024); // 64 blocks
        assert_eq!(m.total_blocks(), 64);
        m.allocate(1, 20).unwrap(); // 2 blocks
        assert_eq!(m.free_blocks(), 62);
        assert_eq!(m.used_tokens(), 20);
        assert_eq!(m.resident_requests(), 1);
        m.grow(1, 12).unwrap(); // 32 tokens → 2 blocks, no extra
        assert_eq!(m.free_blocks(), 62);
        m.grow(1, 1).unwrap(); // 33 tokens → 3 blocks
        assert_eq!(m.free_blocks(), 61);
        assert_eq!(m.free(1).unwrap(), 33);
        assert_eq!(m.free_blocks(), 64);
        assert_eq!(m.used_tokens(), 0);
        assert_eq!(m.resident_requests(), 0);
        m.check_invariants().unwrap();
    }

    #[test]
    fn rejects_double_alloc_and_unknown() {
        let mut m = mgr(256);
        m.allocate(7, 10).unwrap();
        assert_eq!(m.allocate(7, 10), Err(KvError::AlreadyAllocated(7)));
        assert_eq!(m.grow(9, 1), Err(KvError::UnknownRequest(9)));
        assert_eq!(m.free(9), Err(KvError::UnknownRequest(9)));
    }

    #[test]
    fn exhaustion_reports_exact_need() {
        let mut m = mgr(64); // 4 blocks
        m.allocate(1, 33).unwrap(); // 3 blocks
        let err = m.allocate(2, 32).unwrap_err(); // needs 2, free 1
        assert_eq!(err, KvError::OutOfBlocks { needed: 2, free: 1 });
        // State unchanged on failure.
        assert_eq!(m.free_blocks(), 1);
        m.check_invariants().unwrap();
    }

    #[test]
    fn can_grow_predicts_grow() {
        let mut m = mgr(64); // 4 blocks
        m.allocate(1, 16).unwrap(); // 1 block
        assert!(m.can_grow(1, 48)); // 64 tokens → 4 blocks, need 3, free 3
        assert!(!m.can_grow(1, 49));
        assert!(m.can_grow(2, 48)); // fresh alloc prediction
        assert!(!m.can_grow(2, 49));
    }

    #[test]
    fn slot_fast_path_matches_id_path() {
        let mut m = mgr(256); // 16 blocks
        m.allocate(5, 30).unwrap();
        let s = m.slot_of(5).expect("slot for live table");
        assert_eq!(m.slot_of(99), None);
        assert_eq!(m.can_grow_at(s, 2), m.can_grow(5, 2));
        m.grow_at(s, 34).unwrap(); // 64 tokens → 4 blocks
        assert_eq!(m.tokens_of(5), Some(64));
        assert_eq!(m.used_tokens(), 64);
        // Slot survives a swap cycle.
        m.swap_out(5).unwrap();
        assert_eq!(m.slot_of(5), Some(s));
        m.swap_in(5).unwrap();
        assert!(m.can_grow_at(s, 1));
        // Exhaustion through the slot path reports exact need.
        assert!(matches!(m.grow_at(s, 10_000),
                         Err(KvError::OutOfBlocks { .. })));
        m.free(5).unwrap();
        assert_eq!(m.slot_of(5), None);
        m.check_invariants().unwrap();
    }

    #[test]
    fn slots_are_recycled() {
        let mut m = mgr(10_240);
        for id in 0..8u64 {
            m.allocate(id, 16).unwrap();
        }
        let slots_high = m.slots.len();
        for id in 0..8u64 {
            m.free(id).unwrap();
        }
        for id in 100..108u64 {
            m.allocate(id, 16).unwrap();
        }
        assert_eq!(m.slots.len(), slots_high, "freed slots are reused");
        m.check_invariants().unwrap();
    }

    #[test]
    fn swap_out_in_cycle() {
        let mut m = KvBlockManager::new(256, 16, 128);
        m.allocate(1, 40).unwrap(); // 3 blocks
        let before_free = m.free_blocks();
        let toks = m.swap_out(1).unwrap();
        assert_eq!(toks, 40);
        assert_eq!(m.free_blocks(), before_free + 3);
        assert!(m.is_swapped(1));
        assert_eq!(m.used_tokens(), 0);
        assert_eq!(m.resident_requests(), 0);
        m.swap_in(1).unwrap();
        assert!(!m.is_swapped(1));
        assert_eq!(m.free_blocks(), before_free);
        assert_eq!(m.used_tokens(), 40);
        assert_eq!(m.resident_requests(), 1);
        m.check_invariants().unwrap();
        // Freeing a swapped request returns blocks to the swap pool.
        m.swap_out(1).unwrap();
        m.free(1).unwrap();
        m.check_invariants().unwrap();
    }

    #[test]
    fn swap_space_exhaustion() {
        let mut m = KvBlockManager::new(256, 16, 32); // swap: 2 blocks
        m.allocate(1, 48).unwrap(); // 3 blocks
        assert!(matches!(m.swap_out(1),
                         Err(KvError::SwapSpaceExhausted { .. })));
        m.check_invariants().unwrap();
    }

    #[test]
    fn utilization_bounds() {
        let mut m = mgr(160); // 10 blocks
        assert_eq!(m.utilization(), 0.0);
        m.allocate(1, 160).unwrap();
        assert_eq!(m.utilization(), 1.0);
        assert_eq!(KvBlockManager::new(0, 16, 0).utilization(), 1.0);
    }

    /// Property: any interleaving of alloc/grow/free/swap operations
    /// preserves exact block accounting (no leak, no double-free).
    #[test]
    fn prop_no_leaks_under_random_ops() {
        check("kv accounting", 300, |g| {
            let cap = g.u64(64..=2048);
            let block = *g.choose(&[1u32, 8, 16, 32]);
            let mut m = KvBlockManager::new(cap, block, cap / 2);
            let mut live: Vec<RequestId> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..g.usize(1..=120) {
                match g.u64(0..=5) {
                    0 => {
                        let t = g.u64(1..=300) as u32;
                        if m.allocate(next_id, t).is_ok() {
                            live.push(next_id);
                        }
                        next_id += 1;
                    }
                    1 if !live.is_empty() => {
                        let id = *g.choose(&live);
                        if !m.is_swapped(id) {
                            let _ = m.grow(id, g.u64(1..=64) as u32);
                        }
                    }
                    2 if !live.is_empty() => {
                        let i = g.usize(0..=live.len() - 1);
                        let id = live.swap_remove(i);
                        m.free(id).unwrap();
                    }
                    3 if !live.is_empty() => {
                        let id = *g.choose(&live);
                        if !m.is_swapped(id) {
                            let _ = m.swap_out(id);
                        }
                    }
                    4 if !live.is_empty() => {
                        let id = *g.choose(&live);
                        if m.is_swapped(id) {
                            let _ = m.swap_in(id);
                        }
                    }
                    _ => {}
                }
                if let Err(e) = m.check_invariants() {
                    eprintln!("invariant violated: {e}");
                    return false;
                }
            }
            // Drain everything; pool must return to full.
            for id in live {
                m.free(id).unwrap();
            }
            m.free_blocks() == m.total_blocks()
                && m.used_tokens() == 0
                && m.resident_requests() == 0
                && m.check_invariants().is_ok()
        });
    }

    /// Property: the O(1) cached aggregates (`used_tokens`,
    /// `resident_requests`) equal a from-scratch recomputation over the
    /// live ids after every random alloc/grow/free/swap-out/swap-in —
    /// including the mixed slot-handle fast path.
    #[test]
    fn prop_cached_counters_match_recompute() {
        check("kv cached counters", 300, |g| {
            let cap = g.u64(128..=4096);
            let block = *g.choose(&[8u32, 16, 64]);
            let mut m = KvBlockManager::new(cap, block, cap / 2);
            let mut live: Vec<RequestId> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..g.usize(1..=150) {
                match g.u64(0..=5) {
                    0 => {
                        if m.allocate(next_id, g.u64(1..=200) as u32)
                            .is_ok()
                        {
                            live.push(next_id);
                        }
                        next_id += 1;
                    }
                    1 if !live.is_empty() => {
                        let id = *g.choose(&live);
                        if !m.is_swapped(id) {
                            // Exercise the slot fast path half the time.
                            let t = g.u64(1..=48) as u32;
                            if g.u64(0..=1) == 0 {
                                let s = m.slot_of(id).unwrap();
                                let _ = m.grow_at(s, t);
                            } else {
                                let _ = m.grow(id, t);
                            }
                        }
                    }
                    2 if !live.is_empty() => {
                        let i = g.usize(0..=live.len() - 1);
                        m.free(live.swap_remove(i)).unwrap();
                    }
                    3 if !live.is_empty() => {
                        let id = *g.choose(&live);
                        if !m.is_swapped(id) {
                            let _ = m.swap_out(id);
                        }
                    }
                    4 if !live.is_empty() => {
                        let id = *g.choose(&live);
                        if m.is_swapped(id) {
                            let _ = m.swap_in(id);
                        }
                    }
                    _ => {}
                }
                // Recompute from scratch via the public id-keyed API.
                let want_used: u64 = live
                    .iter()
                    .filter(|&&id| !m.is_swapped(id))
                    .map(|&id| m.tokens_of(id).unwrap() as u64)
                    .sum();
                let want_res = live
                    .iter()
                    .filter(|&&id| !m.is_swapped(id))
                    .count();
                if m.used_tokens() != want_used
                    || m.resident_requests() != want_res
                {
                    eprintln!(
                        "cache drift: used {} vs {want_used}, resident {} \
                         vs {want_res}",
                        m.used_tokens(),
                        m.resident_requests()
                    );
                    return false;
                }
            }
            m.check_invariants().is_ok()
        });
    }

    /// Property: used_tokens never exceeds capacity_tokens.
    #[test]
    fn prop_capacity_respected() {
        check("kv capacity", 200, |g| {
            let cap = g.u64(32..=512);
            let mut m = KvBlockManager::new(cap, 16, 0);
            let mut id = 0u64;
            for _ in 0..g.usize(1..=60) {
                let t = g.u64(1..=128) as u32;
                let _ = m.allocate(id, t);
                let _ = m.grow(id, g.u64(1..=32) as u32);
                id += 1;
            }
            m.used_tokens() <= m.capacity_tokens()
                && m.used_blocks() <= m.total_blocks()
        });
    }
}
