//! Paged KV-cache block manager (the vLLM-style memory substrate).
//!
//! GPU KV memory is divided into fixed-size blocks of `block_tokens`
//! tokens. Each running request owns a block table; blocks move between
//! the GPU free pool, request tables, and an (optional) CPU swap pool.
//! The manager is purely accounting — actual tensor storage lives in the
//! engine — but its numbers *are* the memory constraint `M(b_t) ≤ M_max`
//! the paper's Algorithm 1 manages, so its invariants are property-tested
//! hard (no leaks, no double-free, exact token↔block arithmetic).

use crate::request::RequestId;
use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum KvError {
    OutOfBlocks { needed: usize, free: usize },
    UnknownRequest(RequestId),
    AlreadyAllocated(RequestId),
    SwapSpaceExhausted { needed: usize, free: usize },
}

impl std::fmt::Display for KvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KvError::OutOfBlocks { needed, free } => {
                write!(f, "out of KV blocks: need {needed}, free {free}")
            }
            KvError::UnknownRequest(id) => write!(f, "unknown request {id}"),
            KvError::AlreadyAllocated(id) => {
                write!(f, "request {id} already has a block table")
            }
            KvError::SwapSpaceExhausted { needed, free } => {
                write!(f, "swap space exhausted: need {needed}, free {free}")
            }
        }
    }
}

impl std::error::Error for KvError {}

#[derive(Debug, Clone, Default)]
struct Allocation {
    blocks: usize,
    tokens: u32,
    swapped: bool,
}

/// Block-granular KV accounting for one device (or TP group).
#[derive(Debug, Clone)]
pub struct KvBlockManager {
    block_tokens: u32,
    total_blocks: usize,
    free_blocks: usize,
    /// CPU swap pool capacity in blocks (0 disables swapping).
    swap_blocks_total: usize,
    swap_blocks_free: usize,
    tables: BTreeMap<RequestId, Allocation>,
    /// Cumulative counters for telemetry.
    pub stat_allocs: u64,
    pub stat_frees: u64,
    pub stat_swap_outs: u64,
    pub stat_swap_ins: u64,
}

impl KvBlockManager {
    /// `capacity_tokens` is η — the token budget the hardware's KV memory
    /// allows (HardwareSpec::kv_budget / kv_bytes_per_token).
    pub fn new(capacity_tokens: u64, block_tokens: u32,
               swap_capacity_tokens: u64) -> Self {
        assert!(block_tokens > 0);
        let total_blocks = (capacity_tokens / block_tokens as u64) as usize;
        let swap_blocks = (swap_capacity_tokens / block_tokens as u64) as usize;
        KvBlockManager {
            block_tokens,
            total_blocks,
            free_blocks: total_blocks,
            swap_blocks_total: swap_blocks,
            swap_blocks_free: swap_blocks,
            tables: BTreeMap::new(),
            stat_allocs: 0,
            stat_frees: 0,
            stat_swap_outs: 0,
            stat_swap_ins: 0,
        }
    }

    pub fn block_tokens(&self) -> u32 {
        self.block_tokens
    }

    pub fn total_blocks(&self) -> usize {
        self.total_blocks
    }

    pub fn free_blocks(&self) -> usize {
        self.free_blocks
    }

    pub fn used_blocks(&self) -> usize {
        self.total_blocks - self.free_blocks
    }

    /// Capacity in tokens (η, rounded down to block granularity).
    pub fn capacity_tokens(&self) -> u64 {
        self.total_blocks as u64 * self.block_tokens as u64
    }

    /// Tokens currently resident on device (counts whole blocks' reserved
    /// space — the number the utilization gauge reports).
    pub fn used_tokens(&self) -> u64 {
        self.tables
            .values()
            .filter(|a| !a.swapped)
            .map(|a| a.tokens as u64)
            .sum()
    }

    pub fn utilization(&self) -> f64 {
        if self.total_blocks == 0 {
            return 1.0;
        }
        self.used_blocks() as f64 / self.total_blocks as f64
    }

    fn blocks_for(&self, tokens: u32) -> usize {
        tokens.div_ceil(self.block_tokens) as usize
    }

    /// Can `tokens` more tokens be appended for `id` (or allocated fresh)
    /// without exceeding capacity?
    pub fn can_grow(&self, id: RequestId, tokens: u32) -> bool {
        let cur = self.tables.get(&id).map(|a| (a.blocks, a.tokens));
        let (blocks, cur_tokens) = cur.unwrap_or((0, 0));
        let need = self.blocks_for(cur_tokens + tokens) - blocks;
        need <= self.free_blocks
    }

    /// Allocate the initial table for a request's first `tokens` tokens.
    pub fn allocate(&mut self, id: RequestId, tokens: u32)
                    -> Result<(), KvError> {
        if self.tables.contains_key(&id) {
            return Err(KvError::AlreadyAllocated(id));
        }
        let need = self.blocks_for(tokens);
        if need > self.free_blocks {
            return Err(KvError::OutOfBlocks { needed: need,
                                              free: self.free_blocks });
        }
        self.free_blocks -= need;
        self.tables.insert(id, Allocation { blocks: need, tokens,
                                            swapped: false });
        self.stat_allocs += 1;
        Ok(())
    }

    /// Append `tokens` tokens to an existing table (decode growth or the
    /// next prefill chunk), acquiring new blocks as needed.
    pub fn grow(&mut self, id: RequestId, tokens: u32) -> Result<(), KvError> {
        let alloc = self
            .tables
            .get_mut(&id)
            .ok_or(KvError::UnknownRequest(id))?;
        debug_assert!(!alloc.swapped, "grow on swapped request");
        let new_tokens = alloc.tokens + tokens;
        let need_total = new_tokens.div_ceil(self.block_tokens) as usize;
        let extra = need_total.saturating_sub(alloc.blocks);
        if extra > self.free_blocks {
            return Err(KvError::OutOfBlocks { needed: extra,
                                              free: self.free_blocks });
        }
        alloc.blocks = need_total;
        alloc.tokens = new_tokens;
        self.free_blocks -= extra;
        Ok(())
    }

    /// Release a request's blocks (finish or recompute-preemption).
    pub fn free(&mut self, id: RequestId) -> Result<u32, KvError> {
        let alloc = self
            .tables
            .remove(&id)
            .ok_or(KvError::UnknownRequest(id))?;
        if alloc.swapped {
            self.swap_blocks_free += alloc.blocks;
        } else {
            self.free_blocks += alloc.blocks;
        }
        self.stat_frees += 1;
        debug_assert!(self.free_blocks <= self.total_blocks);
        Ok(alloc.tokens)
    }

    /// Move a request's blocks to the CPU pool. Returns the bytes-worth of
    /// blocks moved (in tokens) so the engine can cost the transfer.
    pub fn swap_out(&mut self, id: RequestId) -> Result<u32, KvError> {
        let alloc = self
            .tables
            .get_mut(&id)
            .ok_or(KvError::UnknownRequest(id))?;
        debug_assert!(!alloc.swapped);
        if alloc.blocks > self.swap_blocks_free {
            return Err(KvError::SwapSpaceExhausted {
                needed: alloc.blocks,
                free: self.swap_blocks_free,
            });
        }
        self.swap_blocks_free -= alloc.blocks;
        self.free_blocks += alloc.blocks;
        alloc.swapped = true;
        self.stat_swap_outs += 1;
        Ok(alloc.tokens)
    }

    /// Bring a swapped request back to the device.
    pub fn swap_in(&mut self, id: RequestId) -> Result<u32, KvError> {
        let alloc = self
            .tables
            .get_mut(&id)
            .ok_or(KvError::UnknownRequest(id))?;
        debug_assert!(alloc.swapped);
        if alloc.blocks > self.free_blocks {
            return Err(KvError::OutOfBlocks { needed: alloc.blocks,
                                              free: self.free_blocks });
        }
        self.free_blocks -= alloc.blocks;
        self.swap_blocks_free += alloc.blocks;
        alloc.swapped = false;
        self.stat_swap_ins += 1;
        Ok(alloc.tokens)
    }

    pub fn is_swapped(&self, id: RequestId) -> bool {
        self.tables.get(&id).map(|a| a.swapped).unwrap_or(false)
    }

    pub fn tokens_of(&self, id: RequestId) -> Option<u32> {
        self.tables.get(&id).map(|a| a.tokens)
    }

    pub fn resident_requests(&self) -> usize {
        self.tables.values().filter(|a| !a.swapped).count()
    }

    /// Internal consistency check (used by tests and debug assertions):
    /// free + Σ tables(on-device) == total, same for swap pool.
    pub fn check_invariants(&self) -> Result<(), String> {
        let dev: usize = self
            .tables
            .values()
            .filter(|a| !a.swapped)
            .map(|a| a.blocks)
            .sum();
        if dev + self.free_blocks != self.total_blocks {
            return Err(format!(
                "device leak: used {dev} + free {} != total {}",
                self.free_blocks, self.total_blocks
            ));
        }
        let swp: usize = self
            .tables
            .values()
            .filter(|a| a.swapped)
            .map(|a| a.blocks)
            .sum();
        if swp + self.swap_blocks_free != self.swap_blocks_total {
            return Err(format!(
                "swap leak: used {swp} + free {} != total {}",
                self.swap_blocks_free, self.swap_blocks_total
            ));
        }
        for (id, a) in &self.tables {
            let want = a.tokens.div_ceil(self.block_tokens) as usize;
            if a.blocks != want.max(if a.tokens == 0 { 0 } else { 1 }) {
                return Err(format!(
                    "req {id}: {} tokens in {} blocks (want {want})",
                    a.tokens, a.blocks
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn mgr(tokens: u64) -> KvBlockManager {
        KvBlockManager::new(tokens, 16, tokens)
    }

    #[test]
    fn allocate_grow_free_roundtrip() {
        let mut m = mgr(1024); // 64 blocks
        assert_eq!(m.total_blocks(), 64);
        m.allocate(1, 20).unwrap(); // 2 blocks
        assert_eq!(m.free_blocks(), 62);
        assert_eq!(m.used_tokens(), 20);
        m.grow(1, 12).unwrap(); // 32 tokens → 2 blocks, no extra
        assert_eq!(m.free_blocks(), 62);
        m.grow(1, 1).unwrap(); // 33 tokens → 3 blocks
        assert_eq!(m.free_blocks(), 61);
        assert_eq!(m.free(1).unwrap(), 33);
        assert_eq!(m.free_blocks(), 64);
        m.check_invariants().unwrap();
    }

    #[test]
    fn rejects_double_alloc_and_unknown() {
        let mut m = mgr(256);
        m.allocate(7, 10).unwrap();
        assert_eq!(m.allocate(7, 10), Err(KvError::AlreadyAllocated(7)));
        assert_eq!(m.grow(9, 1), Err(KvError::UnknownRequest(9)));
        assert_eq!(m.free(9), Err(KvError::UnknownRequest(9)));
    }

    #[test]
    fn exhaustion_reports_exact_need() {
        let mut m = mgr(64); // 4 blocks
        m.allocate(1, 33).unwrap(); // 3 blocks
        let err = m.allocate(2, 32).unwrap_err(); // needs 2, free 1
        assert_eq!(err, KvError::OutOfBlocks { needed: 2, free: 1 });
        // State unchanged on failure.
        assert_eq!(m.free_blocks(), 1);
        m.check_invariants().unwrap();
    }

    #[test]
    fn can_grow_predicts_grow() {
        let mut m = mgr(64); // 4 blocks
        m.allocate(1, 16).unwrap(); // 1 block
        assert!(m.can_grow(1, 48)); // 64 tokens → 4 blocks, need 3, free 3
        assert!(!m.can_grow(1, 49));
        assert!(m.can_grow(2, 48)); // fresh alloc prediction
        assert!(!m.can_grow(2, 49));
    }

    #[test]
    fn swap_out_in_cycle() {
        let mut m = KvBlockManager::new(256, 16, 128);
        m.allocate(1, 40).unwrap(); // 3 blocks
        let before_free = m.free_blocks();
        let toks = m.swap_out(1).unwrap();
        assert_eq!(toks, 40);
        assert_eq!(m.free_blocks(), before_free + 3);
        assert!(m.is_swapped(1));
        assert_eq!(m.used_tokens(), 0);
        m.swap_in(1).unwrap();
        assert!(!m.is_swapped(1));
        assert_eq!(m.free_blocks(), before_free);
        m.check_invariants().unwrap();
        // Freeing a swapped request returns blocks to the swap pool.
        m.swap_out(1).unwrap();
        m.free(1).unwrap();
        m.check_invariants().unwrap();
    }

    #[test]
    fn swap_space_exhaustion() {
        let mut m = KvBlockManager::new(256, 16, 32); // swap: 2 blocks
        m.allocate(1, 48).unwrap(); // 3 blocks
        assert!(matches!(m.swap_out(1),
                         Err(KvError::SwapSpaceExhausted { .. })));
        m.check_invariants().unwrap();
    }

    #[test]
    fn utilization_bounds() {
        let mut m = mgr(160); // 10 blocks
        assert_eq!(m.utilization(), 0.0);
        m.allocate(1, 160).unwrap();
        assert_eq!(m.utilization(), 1.0);
        assert_eq!(KvBlockManager::new(0, 16, 0).utilization(), 1.0);
    }

    /// Property: any interleaving of alloc/grow/free/swap operations
    /// preserves exact block accounting (no leak, no double-free).
    #[test]
    fn prop_no_leaks_under_random_ops() {
        check("kv accounting", 300, |g| {
            let cap = g.u64(64..=2048);
            let block = *g.choose(&[1u32, 8, 16, 32]);
            let mut m = KvBlockManager::new(cap, block, cap / 2);
            let mut live: Vec<RequestId> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..g.usize(1..=120) {
                match g.u64(0..=5) {
                    0 => {
                        let t = g.u64(1..=300) as u32;
                        if m.allocate(next_id, t).is_ok() {
                            live.push(next_id);
                        }
                        next_id += 1;
                    }
                    1 if !live.is_empty() => {
                        let id = *g.choose(&live);
                        if !m.is_swapped(id) {
                            let _ = m.grow(id, g.u64(1..=64) as u32);
                        }
                    }
                    2 if !live.is_empty() => {
                        let i = g.usize(0..=live.len() - 1);
                        let id = live.swap_remove(i);
                        m.free(id).unwrap();
                    }
                    3 if !live.is_empty() => {
                        let id = *g.choose(&live);
                        if !m.is_swapped(id) {
                            let _ = m.swap_out(id);
                        }
                    }
                    4 if !live.is_empty() => {
                        let id = *g.choose(&live);
                        if m.is_swapped(id) {
                            let _ = m.swap_in(id);
                        }
                    }
                    _ => {}
                }
                if let Err(e) = m.check_invariants() {
                    eprintln!("invariant violated: {e}");
                    return false;
                }
            }
            // Drain everything; pool must return to full.
            for id in live {
                m.free(id).unwrap();
            }
            m.free_blocks() == m.total_blocks()
                && m.check_invariants().is_ok()
        });
    }

    /// Property: used_tokens never exceeds capacity_tokens.
    #[test]
    fn prop_capacity_respected() {
        check("kv capacity", 200, |g| {
            let cap = g.u64(32..=512);
            let mut m = KvBlockManager::new(cap, 16, 0);
            let mut id = 0u64;
            for _ in 0..g.usize(1..=60) {
                let t = g.u64(1..=128) as u32;
                let _ = m.allocate(id, t);
                let _ = m.grow(id, g.u64(1..=32) as u32);
                id += 1;
            }
            m.used_tokens() <= m.capacity_tokens()
                && m.used_blocks() <= m.total_blocks()
        });
    }
}
