//! Clocks: the scheduler loop is generic over time so the same code path
//! runs against the real PJRT engine (wall time) and the simulated engine
//! (virtual time — Table I replays 1 319 requests in milliseconds).

use std::time::{Duration, Instant};

/// Time source abstraction. All scheduler/metrics timestamps are f64
/// seconds from an arbitrary epoch.
pub trait Clock {
    fn now(&self) -> f64;
    /// Advance by `dt` seconds. Virtual clocks jump; the real clock treats
    /// this as a no-op (real time advances on its own while the engine
    /// executes).
    fn advance(&mut self, dt: f64);
    /// Block until `t` (real clock sleeps; virtual clock jumps).
    fn sleep_until(&mut self, t: f64);
}

/// Wall-clock time from process start.
#[derive(Debug)]
pub struct RealClock {
    start: Instant,
}

impl RealClock {
    pub fn new() -> Self {
        RealClock { start: Instant::now() }
    }
}

impl Default for RealClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for RealClock {
    fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    fn advance(&mut self, _dt: f64) {}

    fn sleep_until(&mut self, t: f64) {
        let now = self.now();
        if t > now {
            std::thread::sleep(Duration::from_secs_f64(t - now));
        }
    }
}

/// Discrete-event virtual clock.
#[derive(Debug, Default)]
pub struct VirtualClock {
    now: f64,
}

impl VirtualClock {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> f64 {
        self.now
    }

    fn advance(&mut self, dt: f64) {
        assert!(dt >= 0.0, "time cannot go backwards (dt={dt})");
        self.now += dt;
    }

    fn sleep_until(&mut self, t: f64) {
        if t > self.now {
            self.now = t;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_clock_advances() {
        let mut c = VirtualClock::new();
        assert_eq!(c.now(), 0.0);
        c.advance(1.5);
        assert_eq!(c.now(), 1.5);
        c.sleep_until(3.0);
        assert_eq!(c.now(), 3.0);
        c.sleep_until(2.0); // no going backwards
        assert_eq!(c.now(), 3.0);
    }

    #[test]
    #[should_panic]
    fn virtual_clock_rejects_negative() {
        VirtualClock::new().advance(-1.0);
    }

    #[test]
    fn real_clock_monotonic() {
        let mut c = RealClock::new();
        let a = c.now();
        c.advance(100.0); // no-op
        let b = c.now();
        assert!(b >= a && b < 1.0);
        let t0 = c.now();
        c.sleep_until(t0 + 0.01);
        assert!(c.now() >= t0 + 0.009);
    }
}
