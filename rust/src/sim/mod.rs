//! Simulation substrate: virtual time for discrete-event runs.

pub mod clock;

pub use clock::{Clock, RealClock, VirtualClock};
