//! Benchmark harness (the offline registry has no `criterion`).
//!
//! Benches are plain binaries (`[[bench]] harness = false`) that build
//! [`Bench`] groups. Each measurement does warmup, then timed iterations
//! until both a minimum iteration count and a minimum wall-time are met,
//! and reports mean / p50 / p99 / throughput in an aligned table — the
//! same information criterion would print, minus the plotting.
//!
//! For the paper-table benches, [`Table`] renders labelled rows (model,
//! static, dynamic, improvement) as GitHub-flavoured markdown so the output
//! can be pasted straight into EXPERIMENTS.md.

use std::time::{Duration, Instant};

/// One timed measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub p50: Duration,
    pub p99: Duration,
    /// Optional units-per-iteration for throughput reporting.
    pub units: Option<(f64, &'static str)>,
}

impl Measurement {
    pub fn throughput(&self) -> Option<String> {
        self.units.map(|(n, unit)| {
            let per_sec = n / self.mean.as_secs_f64();
            format!("{} {unit}/s", human_count(per_sec))
        })
    }
}

fn human_count(x: f64) -> String {
    if x >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.2}k", x / 1e3)
    } else {
        format!("{x:.2}")
    }
}

fn human_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

/// A named group of measurements with shared settings.
pub struct Bench {
    group: String,
    min_iters: u64,
    min_time: Duration,
    warmup: Duration,
    results: Vec<Measurement>,
    quick: bool,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        // DYNABATCH_BENCH_QUICK=1 shrinks budgets (used by `cargo test`
        // smoke-running the bench binaries and by CI).
        let quick = std::env::var("DYNABATCH_BENCH_QUICK").is_ok();
        Bench {
            group: group.to_string(),
            min_iters: if quick { 3 } else { 20 },
            min_time: Duration::from_millis(if quick { 20 } else { 300 }),
            warmup: Duration::from_millis(if quick { 5 } else { 100 }),
            results: Vec::new(),
            quick,
        }
    }

    pub fn quick(&self) -> bool {
        self.quick
    }

    pub fn min_iters(mut self, n: u64) -> Self {
        self.min_iters = n;
        self
    }

    /// Time `f`, which performs ONE logical iteration per call.
    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) -> &Measurement {
        self.bench_units(name, None, f)
    }

    /// Time `f` and report throughput as `units_per_iter` per second.
    pub fn bench_units<F: FnMut()>(
        &mut self,
        name: &str,
        units: Option<(f64, &'static str)>,
        mut f: F,
    ) -> &Measurement {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            f();
        }
        // Timed.
        let mut samples: Vec<Duration> = Vec::new();
        let timed = Instant::now();
        while samples.len() < self.min_iters as usize
            || timed.elapsed() < self.min_time
        {
            let t = Instant::now();
            f();
            samples.push(t.elapsed());
            if samples.len() > 5_000_000 {
                break; // pathological fast function; enough samples
            }
        }
        samples.sort();
        let total: Duration = samples.iter().sum();
        let mean = total / samples.len() as u32;
        let p50 = samples[samples.len() / 2];
        let p99 = samples[((samples.len() * 99) / 100)
            .min(samples.len() - 1)];
        let m = Measurement {
            name: name.to_string(),
            iters: samples.len() as u64,
            mean,
            p50,
            p99,
            units,
        };
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// Print the group as an aligned table.
    pub fn report(&self) {
        println!("\n== bench group: {} ==", self.group);
        println!(
            "{:<40} {:>10} {:>10} {:>10} {:>8} {:>16}",
            "name", "mean", "p50", "p99", "iters", "throughput"
        );
        for m in &self.results {
            println!(
                "{:<40} {:>10} {:>10} {:>10} {:>8} {:>16}",
                m.name,
                human_dur(m.mean),
                human_dur(m.p50),
                human_dur(m.p99),
                m.iters,
                m.throughput().unwrap_or_default()
            );
        }
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }
}

/// Markdown table builder for paper-style result rows.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(&widths) {
                s.push_str(&format!(" {c:<w$} |"));
            }
            s
        };
        let mut out = format!("\n### {}\n\n", self.title);
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{:-<1$}|", "", w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.to_markdown());
    }
}

/// Simple ASCII bar chart (for Fig. 4-style capacity comparisons).
pub fn bar_chart(title: &str, bars: &[(String, f64)], unit: &str) -> String {
    let max = bars.iter().map(|(_, v)| *v).fold(f64::MIN, f64::max).max(1e-9);
    let label_w = bars.iter().map(|(l, _)| l.len()).max().unwrap_or(0);
    let mut out = format!("\n{title}\n");
    for (label, v) in bars {
        let n = ((v / max) * 40.0).round() as usize;
        out.push_str(&format!(
            "  {label:<label_w$} | {:<40} {v:.2} {unit}\n",
            "█".repeat(n)
        ));
    }
    out
}

/// ASCII sparkline of a time series (for Fig. 2-style memory timelines).
pub fn sparkline(xs: &[f64]) -> String {
    const TICKS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    if xs.is_empty() {
        return String::new();
    }
    let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let span = (hi - lo).max(1e-12);
    xs.iter()
        .map(|x| TICKS[(((x - lo) / span) * 7.0).round() as usize])
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        std::env::set_var("DYNABATCH_BENCH_QUICK", "1");
        let mut b = Bench::new("test");
        let m = b.bench("spin", || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(m.iters >= 3);
        assert!(m.mean.as_nanos() > 0);
        assert!(m.p99 >= m.p50);
    }

    #[test]
    fn throughput_reporting() {
        std::env::set_var("DYNABATCH_BENCH_QUICK", "1");
        let mut b = Bench::new("t");
        let m = b
            .bench_units("u", Some((1000.0, "tok")), || {
                std::hint::black_box((0..100).sum::<u64>());
            })
            .clone();
        let t = m.throughput().unwrap();
        assert!(t.contains("tok/s"), "{t}");
    }

    #[test]
    fn table_markdown_shape() {
        let mut t = Table::new("T", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### T"));
        assert!(md.lines().filter(|l| l.starts_with('|')).count() == 3);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_rows() {
        let mut t = Table::new("T", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn bar_chart_and_sparkline() {
        let s = bar_chart("cap", &[("a".into(), 5.4), ("b".into(), 6.6)], "qps");
        assert!(s.contains("5.40 qps") && s.contains("6.60 qps"));
        let sp = sparkline(&[0.0, 0.5, 1.0]);
        assert_eq!(sp.chars().count(), 3);
        assert!(sparkline(&[]).is_empty());
    }
}
