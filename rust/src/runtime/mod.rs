//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! and executes them on the CPU PJRT client via the `xla` crate.
//!
//! Responsibilities: manifest parsing, weight upload (once), executable
//! compilation per (kind, bucket, chunk), and the buffer plumbing that
//! keeps the serving state device-resident across steps (see the state
//! convention in python/compile/model.py — single f32 array, donated).

pub mod manifest;

use anyhow::{anyhow, bail, Context, Result};
use manifest::Manifest;
use std::collections::BTreeMap;
use std::path::Path;
use xla::{HloModuleProto, PjRtBuffer, PjRtClient, PjRtLoadedExecutable,
          XlaComputation};

/// A loaded model: weights on device + compiled executables per variant.
pub struct ModelRuntime {
    pub client: PjRtClient,
    pub manifest: Manifest,
    /// Weight buffers, in manifest order — passed as the leading arguments
    /// of every decode/prefill execution.
    weights: Vec<PjRtBuffer>,
    decode: BTreeMap<u32, PjRtLoadedExecutable>,
    read_tokens: BTreeMap<u32, PjRtLoadedExecutable>,
    /// (bucket, chunk) → prefill executable.
    prefill: BTreeMap<(u32, u32), PjRtLoadedExecutable>,
}

fn compile(client: &PjRtClient, path: &Path) -> Result<PjRtLoadedExecutable> {
    let proto = HloModuleProto::from_text_file(path)
        .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
    let comp = XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow!("compiling {}: {e}", path.display()))
}

impl ModelRuntime {
    /// Load the manifest, upload weights, compile all executables.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let client = PjRtClient::cpu()
            .map_err(|e| anyhow!("creating PJRT CPU client: {e}"))?;

        // Weights: one sequential read, then per-tensor upload.
        let blob = std::fs::read(dir.join(&manifest.weights_file))
            .with_context(|| format!("reading {}", manifest.weights_file))?;
        let mut weights = Vec::with_capacity(manifest.weights.len());
        for w in &manifest.weights {
            let bytes = blob
                .get(w.offset_bytes..w.offset_bytes + w.size_bytes)
                .ok_or_else(|| anyhow!("weight {} out of blob bounds", w.name))?;
            // Little-endian f32s on a little-endian host; avoid the copy a
            // chunked parse would need.
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            let buf = client
                .buffer_from_host_buffer::<f32>(&data, &w.shape, None)
                .map_err(|e| anyhow!("uploading weight {}: {e}", w.name))?;
            weights.push(buf);
        }

        let mut decode = BTreeMap::new();
        let mut read_tokens = BTreeMap::new();
        let mut prefill = BTreeMap::new();
        for (&b, file) in &manifest.decode_files {
            decode.insert(b, compile(&client, &dir.join(file))?);
        }
        for (&b, file) in &manifest.read_tokens_files {
            read_tokens.insert(b, compile(&client, &dir.join(file))?);
        }
        for (&(b, c), file) in &manifest.prefill_files {
            prefill.insert((b, c), compile(&client, &dir.join(file))?);
        }
        if decode.is_empty() {
            bail!("no decode executables in manifest");
        }
        Ok(ModelRuntime { client, manifest, weights, decode, read_tokens,
                          prefill })
    }

    pub fn buckets(&self) -> Vec<u32> {
        self.decode.keys().copied().collect()
    }

    pub fn chunk_sizes(&self) -> Vec<u32> {
        self.manifest.chunk_sizes.clone()
    }

    /// Smallest compiled bucket that fits `n` concurrent slots.
    pub fn bucket_for(&self, n: u32) -> Option<u32> {
        self.decode.keys().copied().find(|&b| b >= n)
    }

    pub fn max_bucket(&self) -> u32 {
        *self.decode.keys().last().unwrap()
    }

    pub fn state_size(&self, bucket: u32) -> usize {
        self.manifest.state_sizes[&bucket]
    }

    /// Fresh zeroed serving state for `bucket` slots.
    pub fn new_state(&self, bucket: u32) -> Result<PjRtBuffer> {
        let n = self.state_size(bucket);
        let zeros = vec![0f32; n];
        self.upload_state(&zeros)
    }

    pub fn upload_state(&self, data: &[f32]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<f32>(data, &[data.len()], None)
            .map_err(|e| anyhow!("uploading state: {e}"))
    }

    pub fn download_state(&self, state: &PjRtBuffer) -> Result<Vec<f32>> {
        let lit = state
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching state: {e}"))?;
        lit.to_vec::<f32>().map_err(|e| anyhow!("state to_vec: {e}"))
    }

    fn i32_buffer(&self, data: &[i32]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<i32>(data, &[data.len()], None)
            .map_err(|e| anyhow!("uploading i32 arg: {e}"))
    }

    fn i32_scalar(&self, v: i32) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<i32>(&[v], &[], None)
            .map_err(|e| anyhow!("uploading i32 scalar: {e}"))
    }

    fn run(&self, exe: &PjRtLoadedExecutable, args: &[&PjRtBuffer])
           -> Result<PjRtBuffer> {
        let mut out = exe
            .execute_b(args)
            .map_err(|e| anyhow!("execute: {e}"))?;
        let replica = out
            .pop()
            .ok_or_else(|| anyhow!("execute returned no replicas"))?;
        replica
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("execute returned no outputs"))
    }

    /// One decode step: consumes `state` (donated to the execution),
    /// returns the new state buffer.
    pub fn decode_step(&self, bucket: u32, state: PjRtBuffer, pos: &[i32],
                       active: &[i32]) -> Result<PjRtBuffer> {
        let exe = self
            .decode
            .get(&bucket)
            .ok_or_else(|| anyhow!("no decode executable for bucket {bucket}"))?;
        debug_assert_eq!(pos.len(), bucket as usize);
        let pos_b = self.i32_buffer(pos)?;
        let act_b = self.i32_buffer(active)?;
        let mut args: Vec<&PjRtBuffer> = self.weights.iter().collect();
        args.push(&state);
        args.push(&pos_b);
        args.push(&act_b);
        self.run(exe, &args)
        // `state` drops here — its device memory was donated.
    }

    /// One prefill chunk for `slot`: consumes and returns the state.
    /// `tokens` is padded to the compiled chunk size internally; callers
    /// must keep chunks within the largest compiled size.
    pub fn prefill_chunk(&self, bucket: u32, state: PjRtBuffer, tokens: &[i32],
                         slot: u32, start: u32) -> Result<PjRtBuffer> {
        let chunk = self
            .chunk_for(tokens.len() as u32)
            .ok_or_else(|| anyhow!("chunk of {} tokens exceeds compiled sizes",
                                   tokens.len()))?;
        let exe = self
            .prefill
            .get(&(bucket, chunk))
            .ok_or_else(|| {
                anyhow!("no prefill executable for bucket {bucket} chunk {chunk}")
            })?;
        let mut padded = tokens.to_vec();
        padded.resize(chunk as usize, self.manifest.pad_id);
        let tok_b = self.i32_buffer(&padded)?;
        let slot_b = self.i32_scalar(slot as i32)?;
        let start_b = self.i32_scalar(start as i32)?;
        let nvalid_b = self.i32_scalar(tokens.len() as i32)?;
        let mut args: Vec<&PjRtBuffer> = self.weights.iter().collect();
        args.push(&state);
        args.push(&tok_b);
        args.push(&slot_b);
        args.push(&start_b);
        args.push(&nvalid_b);
        self.run(exe, &args)
    }

    /// Smallest compiled chunk size that fits `n` tokens.
    pub fn chunk_for(&self, n: u32) -> Option<u32> {
        self.manifest.chunk_sizes.iter().copied().find(|&c| c >= n)
    }

    pub fn max_chunk(&self) -> u32 {
        self.manifest.chunk_sizes.last().copied().unwrap_or(0)
    }

    /// Fetch the [bucket] last-token tail (the only per-step transfer).
    pub fn read_tokens(&self, bucket: u32, state: &PjRtBuffer)
                       -> Result<Vec<i32>> {
        let exe = self
            .read_tokens
            .get(&bucket)
            .ok_or_else(|| anyhow!("no read_tokens for bucket {bucket}"))?;
        let out = self.run(exe, &[state])?;
        let lit = out
            .to_literal_sync()
            .map_err(|e| anyhow!("read_tokens fetch: {e}"))?;
        lit.to_vec::<i32>().map_err(|e| anyhow!("read_tokens to_vec: {e}"))
    }

    /// Repack a downloaded state from one bucket layout into another,
    /// preserving slots `[0, min(old, new))` — bucket migration when the
    /// dynamic batch outgrows (or shrinks well below) the compiled size.
    pub fn repack_state(&self, old: &[f32], old_bucket: u32, new_bucket: u32)
                        -> Vec<f32> {
        let m = &self.manifest;
        let (l, s, h, dh) = (m.n_layers as usize, m.max_seq as usize,
                             m.n_heads as usize, m.d_head as usize);
        let (ob, nb) = (old_bucket as usize, new_bucket as usize);
        debug_assert_eq!(old.len(), 2 * l * ob * s * h * dh + ob);
        let keep = ob.min(nb);
        let row = s * h * dh; // per-slot cache row within one layer plane
        let mut new = vec![0f32; self.state_size(new_bucket)];
        // k then v planes: [L, B, S, H, Dh]
        for plane in 0..2 {
            let o_base = plane * l * ob * row;
            let n_base = plane * l * nb * row;
            for layer in 0..l {
                for slot in 0..keep {
                    let src = o_base + (layer * ob + slot) * row;
                    let dst = n_base + (layer * nb + slot) * row;
                    new[dst..dst + row].copy_from_slice(&old[src..src + row]);
                }
            }
        }
        // token tail
        let o_tail = 2 * l * ob * row;
        let n_tail = 2 * l * nb * row;
        new[n_tail..n_tail + keep].copy_from_slice(&old[o_tail..o_tail + keep]);
        new
    }
}
