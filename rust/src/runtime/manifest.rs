//! Artifact manifest parsing — the contract between `python/compile/aot.py`
//! and the rust runtime (arg order, state layout, file index).

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// The argument convention this runtime implements. aot.py stamps the
/// manifest with the same string; a mismatch means the artifacts predate
/// (or postdate) this loader.
pub const ARG_CONVENTION: &str = "weights-then-state-v2";

#[derive(Debug, Clone)]
pub struct WeightEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset_bytes: usize,
    pub size_bytes: usize,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub model_name: String,
    pub vocab: u32,
    pub d_model: u32,
    pub n_layers: u32,
    pub n_heads: u32,
    pub d_head: u32,
    pub max_seq: u32,
    pub param_count: u64,
    pub kv_bytes_per_token: u64,
    pub seed: u64,
    pub bos_id: i32,
    pub pad_id: i32,
    pub weights_file: String,
    pub weights: Vec<WeightEntry>,
    pub buckets: Vec<u32>,
    pub chunk_sizes: Vec<u32>,
    pub state_sizes: BTreeMap<u32, usize>,
    pub decode_files: BTreeMap<u32, String>,
    pub read_tokens_files: BTreeMap<u32, String>,
    pub prefill_files: BTreeMap<(u32, u32), String>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        Self::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let conv = j.get("arg_convention").as_str().unwrap_or("");
        if conv != ARG_CONVENTION {
            bail!("artifact convention '{conv}' != expected \
                   '{ARG_CONVENTION}' — rebuild with `make artifacts`");
        }
        let model = j.get("model");
        let gu = |v: &Json, k: &str| -> Result<u64> {
            v.get(k).as_u64().with_context(|| format!("manifest {k}"))
        };

        let mut weights = Vec::new();
        for w in j.get("weights").as_arr().context("weights[]")? {
            weights.push(WeightEntry {
                name: w.get("name").as_str().context("weight name")?.into(),
                shape: w
                    .get("shape")
                    .as_arr()
                    .context("weight shape")?
                    .iter()
                    .map(|x| x.as_usize().context("shape dim"))
                    .collect::<Result<_>>()?,
                offset_bytes: w
                    .get("offset_bytes")
                    .as_usize()
                    .context("offset")?,
                size_bytes: w.get("size_bytes").as_usize().context("size")?,
            });
        }
        if weights.is_empty() {
            bail!("manifest has no weights");
        }

        let buckets: Vec<u32> = j
            .get("buckets")
            .as_arr()
            .context("buckets[]")?
            .iter()
            .map(|x| x.as_u64().map(|v| v as u32).context("bucket"))
            .collect::<Result<_>>()?;
        let chunk_sizes: Vec<u32> = j
            .get("chunk_sizes")
            .as_arr()
            .context("chunk_sizes[]")?
            .iter()
            .map(|x| x.as_u64().map(|v| v as u32).context("chunk"))
            .collect::<Result<_>>()?;

        let mut decode_files = BTreeMap::new();
        for (k, v) in j.get("decode").as_obj().context("decode{}")? {
            decode_files.insert(k.parse::<u32>().context("decode bucket")?,
                                v.as_str().context("decode file")?.into());
        }
        let mut read_tokens_files = BTreeMap::new();
        for (k, v) in j.get("read_tokens").as_obj().context("read_tokens{}")? {
            read_tokens_files.insert(
                k.parse::<u32>().context("read bucket")?,
                v.as_str().context("read file")?.into(),
            );
        }
        let mut prefill_files = BTreeMap::new();
        for (k, per) in j.get("prefill").as_obj().context("prefill{}")? {
            let b: u32 = k.parse().context("prefill bucket")?;
            for (ck, v) in per.as_obj().context("prefill chunks")? {
                prefill_files.insert(
                    (b, ck.parse::<u32>().context("prefill chunk")?),
                    v.as_str().context("prefill file")?.to_string(),
                );
            }
        }
        let mut state_sizes = BTreeMap::new();
        for (k, v) in j.get("state_sizes").as_obj().context("state_sizes{}")? {
            state_sizes.insert(k.parse::<u32>().context("state bucket")?,
                               v.as_usize().context("state size")?);
        }

        let m = Manifest {
            model_name: model.get("name").as_str().unwrap_or("?").into(),
            vocab: gu(&model, "vocab")? as u32,
            d_model: gu(&model, "d_model")? as u32,
            n_layers: gu(&model, "n_layers")? as u32,
            n_heads: gu(&model, "n_heads")? as u32,
            d_head: gu(&model, "d_head")? as u32,
            max_seq: gu(&model, "max_seq")? as u32,
            param_count: gu(&model, "param_count")?,
            kv_bytes_per_token: gu(&model, "kv_bytes_per_token")?,
            seed: j.get("seed").as_u64().unwrap_or(0),
            bos_id: j.get("bos_id").as_i64().context("bos_id")? as i32,
            pad_id: j.get("pad_id").as_i64().context("pad_id")? as i32,
            weights_file: j
                .get("weights_file")
                .as_str()
                .context("weights_file")?
                .into(),
            weights,
            buckets,
            chunk_sizes,
            state_sizes,
            decode_files,
            read_tokens_files,
            prefill_files,
        };
        m.validate()?;
        Ok(m)
    }

    fn validate(&self) -> Result<()> {
        for &b in &self.buckets {
            if !self.decode_files.contains_key(&b) {
                bail!("bucket {b}: missing decode executable");
            }
            if !self.read_tokens_files.contains_key(&b) {
                bail!("bucket {b}: missing read_tokens executable");
            }
            if !self.state_sizes.contains_key(&b) {
                bail!("bucket {b}: missing state size");
            }
            let expect = 2
                * self.n_layers as usize
                * b as usize
                * self.max_seq as usize
                * self.n_heads as usize
                * self.d_head as usize
                + b as usize;
            if self.state_sizes[&b] != expect {
                bail!("bucket {b}: state size {} != computed {expect}",
                      self.state_sizes[&b]);
            }
            for &c in &self.chunk_sizes {
                if !self.prefill_files.contains_key(&(b, c)) {
                    bail!("bucket {b} chunk {c}: missing prefill executable");
                }
            }
        }
        // Weight table must be contiguous from 0.
        let mut offset = 0;
        for w in &self.weights {
            if w.offset_bytes != offset {
                bail!("weight {}: offset {} != expected {offset}", w.name,
                      w.offset_bytes);
            }
            let elems: usize = w.shape.iter().product();
            if elems * 4 != w.size_bytes {
                bail!("weight {}: size/shape mismatch", w.name);
            }
            offset += w.size_bytes;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn minimal_json() -> String {
        r#"{
          "arg_convention": "weights-then-state-v2",
          "model": {"name": "micro", "vocab": 258, "d_model": 32,
                    "n_layers": 2, "n_heads": 2, "d_head": 16,
                    "max_seq": 32, "param_count": 100,
                    "kv_bytes_per_token": 512},
          "seed": 0, "bos_id": 256, "pad_id": 257,
          "weights_file": "weights.bin",
          "weights": [{"name": "w0", "shape": [2, 3],
                       "offset_bytes": 0, "size_bytes": 24}],
          "buckets": [1],
          "chunk_sizes": [4],
          "state_sizes": {"1": 4097},
          "decode": {"1": "d1.hlo.txt"},
          "read_tokens": {"1": "r1.hlo.txt"},
          "prefill": {"1": {"4": "p1.hlo.txt"}}
        }"#
        .to_string()
    }

    #[test]
    fn parses_minimal() {
        let j = Json::parse(&minimal_json()).unwrap();
        let m = Manifest::from_json(&j).unwrap();
        assert_eq!(m.model_name, "micro");
        assert_eq!(m.buckets, vec![1]);
        assert_eq!(m.state_sizes[&1], 4097);
        assert_eq!(m.prefill_files[&(1, 4)], "p1.hlo.txt");
        assert_eq!(m.pad_id, 257);
    }

    #[test]
    fn rejects_wrong_convention() {
        let s = minimal_json().replace("-v2", "-v1");
        let j = Json::parse(&s).unwrap();
        let err = Manifest::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("convention"), "{err}");
    }

    #[test]
    fn rejects_bad_state_size() {
        let s = minimal_json().replace("4097", "999");
        let j = Json::parse(&s).unwrap();
        assert!(Manifest::from_json(&j).is_err());
    }

    #[test]
    fn rejects_missing_prefill() {
        let s = minimal_json().replace(r#""prefill": {"1": {"4": "p1.hlo.txt"}}"#,
                                       r#""prefill": {"1": {}}"#);
        let j = Json::parse(&s).unwrap();
        assert!(Manifest::from_json(&j).is_err());
    }

    #[test]
    fn rejects_noncontiguous_weights() {
        let s = minimal_json().replace(r#""offset_bytes": 0"#,
                                       r#""offset_bytes": 8"#);
        let j = Json::parse(&s).unwrap();
        assert!(Manifest::from_json(&j).is_err());
    }
}
