//! Experiment driver: wires workload → scheduler → engine → metrics, in
//! virtual time (simulation) or wall time (real engine), plus the capacity
//! search used by Table II / Fig. 4, mid-run policy-switch scenarios
//! (`run_sim_switched`, swept over switch time × spike magnitude by
//! [`switch_sweep`]), and the multi-replica co-simulation
//! ([`run_replica_sim`]) behind the `dynabatch route` subcommand — N
//! independent scheduler+engine replicas in virtual time with arrivals
//! dispatched by a [`RoutePolicy`], reporting per-replica and aggregate
//! [`RunMetrics`] so router overhead and scaling regress deterministically.
//!
//! On top of the replica co-simulation sits the fleet co-simulation
//! ([`run_fleet_sim`], behind `dynabatch fleet`): heterogeneous
//! [`ReplicaProfile`]s, a [`FleetController`](crate::service::fleet)
//! ticked in virtual time that spawns and retires replicas mid-run, and
//! cost accounting in cost units (replica-seconds × profile cost) —
//! swept into a deterministic cost/SLA frontier by [`fleet_frontier`].
//!
//! This is the offline twin of the [`crate::service`] layer: both drive
//! the same priority-aware scheduler, so requests may carry classes and
//! deadlines here too. Deadlines on this path are *absolute* scheduler
//! clock values (the service converts relative deadlines at acceptance);
//! shed/cancel/reject counts surface in [`RunMetrics`].

use crate::config::{FleetPolicyKind, HardwareSpec, ModelSpec, PolicyKind,
                    ReplicaProfile, SchedulerConfig};
use crate::engine::sim::SimEngine;
use crate::engine::Engine;
use crate::metrics::{ChaosMetrics, FleetMetrics, ReplicaSetMetrics,
                     RunMetrics};
use crate::request::{PriorityClass, Request, RequestId};
use crate::scheduler::{SchedStats, Scheduler};
use crate::service::fleet::{build_fleet_controller, FleetController,
                            FleetDirective, FleetObservation};
use crate::service::replica::{Health, HealthPolicy, HealthTracker,
                              ReplicaLoad, RouteKey, RoutePolicy};
use crate::sim::{Clock, VirtualClock};
use crate::util::json::Json;
use crate::util::stats::percentile_of;
use crate::workload::{Arrival, Workload};
use anyhow::{bail, Result};
use std::collections::{HashMap, HashSet};

/// A fully-specified simulation scenario.
#[derive(Debug, Clone)]
pub struct SimScenario {
    pub model: ModelSpec,
    pub hardware: HardwareSpec,
    pub sched: SchedulerConfig,
    pub workload: Workload,
    /// Override η (KV token capacity); None derives it from the hardware.
    pub eta_tokens_override: Option<u64>,
    /// CPU swap pool in tokens (swap preemption headroom).
    pub swap_tokens: u64,
}

impl SimScenario {
    pub fn eta_tokens(&self) -> u64 {
        self.eta_tokens_override.unwrap_or_else(|| {
            self.hardware.kv_budget(&self.model)
                / self.model.kv_bytes_per_token().max(1)
        })
    }
}

/// One scheduled controller hot-swap for [`run_loop_switched`] /
/// [`run_sim_switched`]: at clock time `at`, reconfigure to `to`.
#[derive(Debug, Clone)]
pub struct PolicySwitch {
    pub at: f64,
    pub to: PolicyKind,
}

/// Run any engine+clock against a request list until completion (or
/// `max_steps`, a safety net against livelock).
pub fn run_loop<E: Engine + ?Sized, C: Clock>(
    sched: &mut Scheduler,
    engine: &mut E,
    clock: &mut C,
    requests: Vec<Request>,
    max_steps: u64,
) -> Result<()> {
    run_loop_switched(sched, engine, clock, requests, max_steps, &[])
}

/// [`run_loop`] with mid-run controller hot-swaps: each switch fires at
/// the first iteration whose clock has reached its `at` time (switches
/// must be sorted by `at`).
pub fn run_loop_switched<E: Engine + ?Sized, C: Clock>(
    sched: &mut Scheduler,
    engine: &mut E,
    clock: &mut C,
    mut requests: Vec<Request>,
    max_steps: u64,
    switches: &[PolicySwitch],
) -> Result<()> {
    requests.sort_by(|a, b| a.arrived_at.total_cmp(&b.arrived_at));
    let mut next = 0usize;
    let mut next_switch = 0usize;
    let mut steps = 0u64;
    while steps < max_steps {
        let now = clock.now();
        while next_switch < switches.len()
            && switches[next_switch].at <= now
        {
            sched.reconfigure(switches[next_switch].to.clone())?;
            next_switch += 1;
        }
        while next < requests.len() && requests[next].arrived_at <= now {
            let mut r = requests[next].clone();
            r.arrived_at = r.arrived_at.max(0.0);
            sched.submit(r);
            next += 1;
        }
        if !sched.has_work() {
            if next >= requests.len() {
                break; // drained
            }
            clock.sleep_until(requests[next].arrived_at);
            continue;
        }
        match sched.step(engine, clock.now())? {
            Some(elapsed) => clock.advance(elapsed),
            None => {
                // Work exists but nothing runnable (e.g. queue gated behind
                // b_t while batch drains): advance to the next event.
                if next < requests.len() {
                    clock.sleep_until(requests[next].arrived_at);
                } else {
                    // Nothing can ever unblock — should not happen; bail
                    // via the step budget rather than spinning.
                    clock.advance(1e-3);
                }
            }
        }
        steps += 1;
    }
    Ok(())
}

/// Run one simulated scenario to completion and compute metrics.
pub fn run_sim(scenario: &SimScenario) -> Result<RunMetrics> {
    run_sim_switched(scenario, &[])
}

/// [`run_sim`] with mid-run controller hot-swaps (the policy-switch
/// scenario behind the `dynabatch switch` subcommand): the scenario
/// starts on `scenario.sched.policy` and reconfigures live at each
/// switch point. The reported policy label is the final controller's.
pub fn run_sim_switched(scenario: &SimScenario, switches: &[PolicySwitch])
                        -> Result<RunMetrics> {
    run_sim_with_requests(scenario, scenario.workload.generate(), switches)
}

/// [`run_sim_switched`] over an explicit request list instead of the
/// scenario's generated workload — the hook for composed populations
/// (e.g. [`switch_sweep`]'s base traffic + injected spike burst).
pub fn run_sim_with_requests(scenario: &SimScenario,
                             requests: Vec<Request>,
                             switches: &[PolicySwitch])
                             -> Result<RunMetrics> {
    let mut engine = SimEngine::new(&scenario.model, &scenario.hardware);
    let mut sched = Scheduler::new(
        scenario.sched.clone(),
        scenario.eta_tokens(),
        scenario.swap_tokens,
        scenario.workload.prompt_mean(),
        scenario.workload.output.mean(),
    );
    // Experiment path: keep exact full-run traces (the serve path keeps
    // the bounded rings instead).
    sched.retain_full_traces();
    sched.telemetry.set_prior_variances(
        scenario.workload.prompt_variance(),
        scenario.workload.output.variance(),
    );
    let mut clock = VirtualClock::new();
    let n = requests.len() as u64;
    // Generous budget: every request needs ≲ prompt_chunks + outputs steps;
    // preemption storms can multiply it.
    let max_steps = (n * 4096).max(1_000_000);
    run_loop_switched(&mut sched, &mut engine, &mut clock, requests,
                      max_steps, switches)?;
    let makespan = clock.now();
    let mut m = RunMetrics::compute(
        sched.controller_label(),
        sched.finished(),
        &sched.stats,
        &sched.decode_latencies.to_vec(),
        makespan,
        engine.utilization(),
    );
    // Per-class SLA targets follow the policy that *ended* the run —
    // the same convention as the reported policy label (mid-run
    // switches re-govern the loop, so violation rates are measured
    // against the final controller's targets).
    let final_policy = switches
        .last()
        .map(|s| &s.to)
        .unwrap_or(&scenario.sched.policy);
    m.attach_class_stats(
        class_latency_traces(&sched),
        sched.finished(),
        &final_policy.sla_targets(scenario.sched.d_sla),
        scenario.sched.eps_d,
    );
    if sched.kv.prefix_enabled() {
        m.prefix_hit_rate = Some(sched.kv.prefix_hit_rate());
    }
    if scenario.sched.padded_prefill {
        m.padded_prefill_tokens =
            Some(sched.telemetry.prefill_padded_tokens());
        m.padding_waste = Some(sched.telemetry.padding_waste());
    }
    Ok(m)
}

/// The telemetry's per-class attributed decode-latency traces, rank
/// order (the per-class half of `RunMetrics`).
fn class_latency_traces(sched: &Scheduler) -> Vec<Vec<f64>> {
    (0..PriorityClass::COUNT)
        .map(|rank| sched.telemetry.class_latencies(rank).to_vec())
        .collect()
}

/// One replica of the virtual-time co-simulation: its own scheduler,
/// engine and clock — the offline twin of a `Service` replica.
struct SimReplica {
    sched: Scheduler,
    engine: SimEngine,
    clock: VirtualClock,
}

impl SimReplica {
    fn load(&self) -> ReplicaLoad {
        ReplicaLoad {
            waiting: self.sched.waiting_by_class().iter().sum(),
            running: self.sched.running_len() as u32,
            resuming: self.sched.resume_len() as u32,
            // Queue depths are read synchronously here — there is no
            // published-snapshot lag to correct for.
            in_flight_to: 0,
            kv_free_blocks: self.sched.kv.free_blocks(),
            kv_total_blocks: self.sched.kv.total_blocks(),
            // Same per-class SLA headroom signals the live router reads
            // off replica snapshots.
            class_p95: std::array::from_fn(|rank| {
                self.sched.telemetry.decode_latency_class_p(rank, 95.0)
            }),
            class_ttft_p95: std::array::from_fn(|rank| {
                self.sched.telemetry.ttft_class_p(rank, 95.0)
            }),
            // decode_speed / cost_unit keep their neutral defaults; the
            // fleet sim overlays its per-replica profile on top.
            ..ReplicaLoad::default()
        }
    }
}

/// Route the next request by `route` over the replicas' live loads and
/// submit it. An idle target's clock is pulled forward to the arrival
/// so latencies never run backwards.
fn route_one(reps: &mut [SimReplica], requests: &[Request],
             next: &mut usize, route: &RoutePolicy, rr: &mut usize) {
    let loads: Vec<ReplicaLoad> = reps.iter().map(|r| r.load()).collect();
    let req = &requests[*next];
    let key = RouteKey::new(req.class, req.prompt_len as usize);
    let i = route.pick(key, &loads, *rr).unwrap_or(0); // never drains
    *rr += 1;
    let mut req = req.clone();
    req.arrived_at = req.arrived_at.max(0.0);
    reps[i].clock.sleep_until(req.arrived_at);
    reps[i].sched.submit(req);
    *next += 1;
}

/// Run `scenario`'s workload through `n_replicas` independently
/// scheduled replicas in virtual time, dispatching each arrival with
/// `route` (the same [`RoutePolicy`] object the live
/// [`crate::service::ReplicaSet`] uses, fed from scheduler queue depths
/// instead of service snapshots). Event order: the replica with work and
/// the earliest clock steps next; arrivals are routed when the
/// simulation time front reaches them. Fully deterministic for a fixed
/// workload seed — the regression base for router scaling and overhead.
///
/// Returns per-replica [`RunMetrics`] plus the set aggregate (tokens
/// summed, makespan = the slowest replica, percentiles over the
/// concatenated decode-latency records).
pub fn run_replica_sim(scenario: &SimScenario, n_replicas: usize,
                       route: &RoutePolicy) -> Result<ReplicaSetMetrics> {
    if n_replicas == 0 {
        bail!("run_replica_sim needs at least one replica");
    }
    route.validate(n_replicas)?;
    let mut reps: Vec<SimReplica> = (0..n_replicas)
        .map(|_| {
            let mut sched = Scheduler::new(
                scenario.sched.clone(),
                scenario.eta_tokens(),
                scenario.swap_tokens,
                scenario.workload.prompt_mean(),
                scenario.workload.output.mean(),
            );
            sched.retain_full_traces();
            sched.telemetry.set_prior_variances(
                scenario.workload.prompt_variance(),
                scenario.workload.output.variance(),
            );
            SimReplica {
                sched,
                engine: SimEngine::new(&scenario.model,
                                       &scenario.hardware),
                clock: VirtualClock::new(),
            }
        })
        .collect();
    let requests = scenario.workload.generate();
    let mut next = 0usize;
    let mut rr = 0usize;
    let max_steps = (requests.len() as u64 * 4096).max(1_000_000);
    let mut steps = 0u64;
    loop {
        // The replica with work and the earliest clock steps next.
        let mut active: Option<usize> = None;
        for (i, r) in reps.iter().enumerate() {
            if !r.sched.has_work() {
                continue;
            }
            let earlier = match active {
                None => true,
                Some(b) => r.clock.now() < reps[b].clock.now(),
            };
            if earlier {
                active = Some(i);
            }
        }
        match active {
            Some(i) => {
                let now = reps[i].clock.now();
                if next < requests.len()
                    && requests[next].arrived_at <= now
                {
                    // Dispatch everything the time front has reached,
                    // then re-pick — routing may wake an earlier clock.
                    while next < requests.len()
                        && requests[next].arrived_at <= now
                    {
                        route_one(&mut reps, &requests, &mut next, route,
                                  &mut rr);
                    }
                    continue;
                }
                let r = &mut reps[i];
                match r.sched.step(&mut r.engine, now)? {
                    Some(elapsed) => r.clock.advance(elapsed),
                    None => {
                        // Work exists but nothing runnable: advance to
                        // the next event.
                        if next < requests.len() {
                            let t = requests[next].arrived_at;
                            r.clock.sleep_until(t.max(now + 1e-3));
                        } else {
                            r.clock.advance(1e-3);
                        }
                    }
                }
                steps += 1;
                if steps >= max_steps {
                    break;
                }
            }
            None => {
                if next >= requests.len() {
                    break; // drained everywhere
                }
                // Every replica idle: route the next arrival (its
                // target's clock jumps to the arrival time).
                route_one(&mut reps, &requests, &mut next, route, &mut rr);
            }
        }
    }

    let sims: Vec<&SimReplica> = reps.iter().collect();
    Ok(fold_replica_set(&sims, scenario, route.label()))
}

/// Fold N finished simulated replicas into per-replica [`RunMetrics`]
/// plus the set aggregate (tokens summed, makespan = the slowest
/// replica, percentiles over the concatenated records) — shared by
/// [`run_replica_sim`] and [`run_fleet_sim`].
fn fold_replica_set(reps: &[&SimReplica], scenario: &SimScenario,
                    route_label: String) -> ReplicaSetMetrics {
    let targets = scenario.sched.policy.sla_targets(scenario.sched.d_sla);
    let mut all_finished: Vec<Request> = Vec::new();
    let mut all_lat: Vec<f64> = Vec::new();
    let mut all_class_lat: Vec<Vec<f64>> =
        vec![Vec::new(); PriorityClass::COUNT];
    let mut agg_stats = SchedStats::default();
    let mut per_replica = Vec::with_capacity(reps.len());
    let mut agg_makespan = 0.0f64;
    let mut util_sum = 0.0f64;
    let mut util_n = 0usize;
    for r in reps {
        let makespan = r.clock.now();
        agg_makespan = agg_makespan.max(makespan);
        let lat = r.sched.decode_latencies.to_vec();
        let class_lat = class_latency_traces(&r.sched);
        let mut m = RunMetrics::compute(
            r.sched.controller_label(),
            r.sched.finished(),
            &r.sched.stats,
            &lat,
            makespan,
            r.engine.utilization(),
        );
        for (acc, trace) in all_class_lat.iter_mut().zip(&class_lat) {
            acc.extend_from_slice(trace);
        }
        m.attach_class_stats(class_lat, r.sched.finished(), &targets,
                             scenario.sched.eps_d);
        if let Some(u) = m.utilization {
            util_sum += u;
            util_n += 1;
        }
        agg_stats.absorb(&r.sched.stats);
        all_finished.extend_from_slice(r.sched.finished());
        all_lat.extend_from_slice(&lat);
        per_replica.push(m);
    }
    let mut aggregate = RunMetrics::compute(
        reps[0].sched.controller_label(),
        &all_finished,
        &agg_stats,
        &all_lat,
        agg_makespan,
        if util_n > 0 {
            Some(util_sum / util_n as f64)
        } else {
            None
        },
    );
    aggregate.attach_class_stats(all_class_lat, &all_finished, &targets,
                                 scenario.sched.eps_d);
    ReplicaSetMetrics {
        route_policy: route_label,
        n_replicas: reps.len(),
        per_replica,
        aggregate,
    }
}

/// Hedge duplicates live in a disjoint request-id space so they can
/// coexist with any original id on the same replica: duplicate of
/// request `id` is `HEDGE_BASE + id`.
pub const HEDGE_BASE: RequestId = 1 << 40;

/// One injected fault for the chaos co-simulation ([`run_chaos_sim`]).
#[derive(Debug, Clone)]
pub enum Fault {
    /// The replica dies at virtual time `at`: its in-flight population
    /// is torn down ([`Scheduler::crash_extract`]) — prompt-intact
    /// requests re-route to a healthy replica, streamed ones end with a
    /// typed terminal error — and it never steps again.
    Crash { replica: usize, at: f64 },
    /// Straggler: the replica's per-step time is multiplied by `factor`
    /// from `at` to `at + duration` (threaded through
    /// [`SimEngine::set_slow`]).
    Slow { replica: usize, at: f64, factor: f64, duration: f64 },
    /// The replicas are unreachable from `at` to `at + duration`: they
    /// stop stepping (in-flight work stalls, nothing is lost), take no
    /// new routes, and drain their backlog after healing.
    Partition { replicas: Vec<usize>, at: f64, duration: f64 },
}

/// A chaos-run configuration: the fault schedule plus the detection
/// and mitigation knobs layered on the replica co-simulation.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    pub faults: Vec<Fault>,
    /// Straggler-detector tuning; the [`HealthTracker`] it drives is
    /// the same state machine the live [`crate::service::ReplicaSet`]
    /// runs.
    pub health: HealthPolicy,
    /// Virtual-time spacing of straggler-detector observations.
    pub observe_interval: f64,
    /// Duplicate-submit interactive prompt-intact requests off a
    /// newly-`Suspect` replica; first token wins, the loser is
    /// cancelled via the O(1) cancel path.
    pub hedging: bool,
    /// Traffic mix for [`assign_classes`] (all-zero leaves every
    /// request on its generated class).
    pub mix: [f64; PriorityClass::COUNT],
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            faults: Vec::new(),
            health: HealthPolicy::default(),
            observe_interval: 0.25,
            hedging: true,
            mix: [0.0; PriorityClass::COUNT],
        }
    }
}

impl FaultPlan {
    pub fn validate(&self, n_replicas: usize) -> Result<()> {
        if self.observe_interval <= 0.0
            || !self.observe_interval.is_finite()
        {
            bail!("fault plan needs a positive finite observe interval");
        }
        if self.health.suspect_factor <= 1.0
            || !self.health.suspect_factor.is_finite()
        {
            bail!("health.suspect_factor must be > 1 (a replica cannot \
                   straggle behind itself)");
        }
        let check = |replica: usize, at: f64| -> Result<()> {
            if replica >= n_replicas {
                bail!("fault targets replica {replica} but the sim has \
                       {n_replicas}");
            }
            if at < 0.0 || !at.is_finite() {
                bail!("fault time must be finite and >= 0, got {at}");
            }
            Ok(())
        };
        for f in &self.faults {
            match f {
                Fault::Crash { replica, at } => check(*replica, *at)?,
                Fault::Slow { replica, at, factor, duration } => {
                    check(*replica, *at)?;
                    if *factor <= 0.0 || !factor.is_finite() {
                        bail!("slow factor must be finite and > 0");
                    }
                    if *duration <= 0.0 || duration.is_nan() {
                        bail!("slow duration must be > 0");
                    }
                }
                Fault::Partition { replicas, at, duration } => {
                    if replicas.is_empty() {
                        bail!("partition needs at least one replica");
                    }
                    for &r in replicas {
                        check(r, *at)?;
                    }
                    if *duration <= 0.0 || !duration.is_finite() {
                        bail!("partition duration must be > 0");
                    }
                }
            }
        }
        Ok(())
    }

    /// Expand the plan into per-replica point events, sorted by time
    /// (stable: plan order breaks ties) — the deterministic application
    /// schedule [`run_chaos_sim`] consumes.
    fn events(&self) -> Vec<(f64, ChaosEvent)> {
        let mut ev = Vec::new();
        for f in &self.faults {
            match f {
                Fault::Crash { replica, at } => {
                    ev.push((*at, ChaosEvent::Crash(*replica)));
                }
                Fault::Slow { replica, at, factor, duration } => {
                    ev.push((*at, ChaosEvent::SlowStart(*replica,
                                                        *factor)));
                    ev.push((*at + *duration,
                             ChaosEvent::SlowEnd(*replica)));
                }
                Fault::Partition { replicas, at, duration } => {
                    for &r in replicas {
                        ev.push((*at, ChaosEvent::PartitionStart(r)));
                        ev.push((*at + *duration,
                                 ChaosEvent::PartitionEnd(r)));
                    }
                }
            }
        }
        ev.sort_by(|a, b| a.0.total_cmp(&b.0));
        ev
    }

    /// The fault activity envelope `[start, end)` used to bucket
    /// finished requests into pre/during/post phases by arrival time.
    /// A crash never ends, so its envelope runs to +∞ (empty post
    /// phase); an empty plan yields an empty during phase.
    fn envelope(&self) -> (f64, f64) {
        let mut start = f64::INFINITY;
        let mut end = f64::NEG_INFINITY;
        for f in &self.faults {
            let (at, until) = match f {
                Fault::Crash { at, .. } => (*at, f64::INFINITY),
                Fault::Slow { at, duration, .. } => (*at, *at + *duration),
                Fault::Partition { at, duration, .. } => {
                    (*at, *at + *duration)
                }
            };
            start = start.min(at);
            end = end.max(until);
        }
        (start, end)
    }
}

/// A [`FaultPlan`] fault expanded to a single-replica point event.
#[derive(Debug, Clone, Copy)]
enum ChaosEvent {
    Crash(usize),
    SlowStart(usize, f64),
    SlowEnd(usize),
    PartitionStart(usize),
    PartitionEnd(usize),
}

/// One live hedge pair: the original runs on `orig_rep`, its duplicate
/// (`HEDGE_BASE + id`) on `dup_rep`; first token wins.
#[derive(Debug, Clone, Copy)]
struct Hedge {
    orig_rep: usize,
    dup_rep: usize,
}

/// The chaos counters accumulated while the simulation runs (the rest
/// of [`ChaosMetrics`] is computed from the finished replicas).
#[derive(Debug, Default)]
struct ChaosCounters {
    crashes: u64,
    partitions: u64,
    suspected: u64,
    recovered: u64,
    lost: u64,
    rerouted: u64,
    hedged: u64,
    hedge_wins: u64,
    duplicates_suppressed: u64,
}

/// The chaos co-simulation's mutable state: the replicas plus fault
/// flags, the health tracker driving routing exclusion, the event and
/// observation schedules on the monotone time front, and the hedge
/// book-keeping.
struct ChaosSim<'a> {
    reps: Vec<SimReplica>,
    requests: Vec<Request>,
    next: usize,
    route: &'a RoutePolicy,
    rr: usize,
    health: HealthTracker,
    crashed: Vec<bool>,
    partitioned: Vec<bool>,
    events: Vec<(f64, ChaosEvent)>,
    next_event: usize,
    /// Monotone virtual-time front: the max time any replica or arrival
    /// has reached — faults and detector observations fire on it.
    front: f64,
    next_observe: f64,
    observe_interval: f64,
    hedging: bool,
    /// Request id → index into `requests` (for hedge duplication).
    by_index: HashMap<RequestId, usize>,
    /// Original ids that were accepted somewhere (the zero-loss ledger).
    assigned: HashMap<RequestId, usize>,
    hedges: HashMap<RequestId, Hedge>,
    /// Each request is hedged at most once, ever.
    hedged_ever: HashSet<RequestId>,
    m: ChaosCounters,
}

impl ChaosSim<'_> {
    /// Advance the time front and fire, in time order, every fault
    /// event and detector observation it crossed (ties: faults first).
    fn advance_front(&mut self, t: f64) {
        if t > self.front {
            self.front = t;
        }
        loop {
            let ev_at = self.events.get(self.next_event).map(|e| e.0);
            let ev_due = ev_at.is_some_and(|at| at <= self.front);
            let ob_due = self.next_observe <= self.front;
            if ev_due
                && (!ob_due
                    || ev_at.is_some_and(|at| at <= self.next_observe))
            {
                let (at, ev) = self.events[self.next_event];
                self.next_event += 1;
                self.apply_event(at, ev);
            } else if ob_due {
                self.next_observe += self.observe_interval;
                self.observe();
            } else {
                break;
            }
        }
    }

    fn apply_event(&mut self, at: f64, ev: ChaosEvent) {
        match ev {
            ChaosEvent::Crash(i) => {
                if self.crashed[i] {
                    return;
                }
                self.crashed[i] = true;
                self.partitioned[i] = false;
                self.health.mark_down(i);
                self.m.crashes += 1;
                let SimReplica { sched, engine, clock } =
                    &mut self.reps[i];
                let now = clock.now().max(at);
                let intact = sched.crash_extract(engine, now);
                for req in intact {
                    self.reroute(req);
                }
            }
            ChaosEvent::SlowStart(i, factor) => {
                if !self.crashed[i] {
                    self.reps[i].engine.set_slow(Some(factor));
                }
            }
            ChaosEvent::SlowEnd(i) => {
                self.reps[i].engine.set_slow(None);
            }
            ChaosEvent::PartitionStart(i) => {
                if !self.crashed[i] && !self.partitioned[i] {
                    self.partitioned[i] = true;
                    self.health.mark_down(i);
                    self.m.partitions += 1;
                }
            }
            ChaosEvent::PartitionEnd(i) => {
                if self.partitioned[i] {
                    self.partitioned[i] = false;
                    // The replica was frozen for the whole outage: its
                    // clock jumps to the heal time, then it drains.
                    self.reps[i].clock.sleep_until(at);
                    self.health.mark_recovering(i);
                    self.m.recovered += 1;
                }
            }
        }
    }

    /// One straggler-detector pass over the per-replica decode p95s
    /// (worst class wins — the same signal
    /// `ReplicaSet::observe_health` reads off live snapshots). Newly
    /// suspect replicas trigger hedging.
    fn observe(&mut self) {
        let p95: Vec<f64> = self
            .reps
            .iter()
            .map(|r| {
                (0..PriorityClass::COUNT)
                    .map(|rank| {
                        r.sched
                            .telemetry
                            .decode_latency_class_p(rank, 95.0)
                    })
                    .fold(0.0, f64::max)
            })
            .collect();
        let newly = self.health.observe(&p95);
        self.m.suspected += newly.len() as u64;
        if self.hedging {
            for i in newly {
                self.hedge_off(i);
            }
        }
    }

    /// Duplicate-submit every interactive prompt-intact request on the
    /// newly suspect replica `i` to a healthy peer: first token wins,
    /// the loser is cancelled when [`Self::resolve_hedges`] sees a
    /// winner.
    fn hedge_off(&mut self, i: usize) {
        for id in self.reps[i].sched.prompt_intact_ids() {
            if id >= HEDGE_BASE
                || self.hedges.contains_key(&id)
                || self.hedged_ever.contains(&id)
            {
                continue;
            }
            let Some(&idx) = self.by_index.get(&id) else { continue };
            if self.requests[idx].class != PriorityClass::Interactive {
                continue;
            }
            let prompt_len = self.requests[idx].prompt_len as usize;
            let picked =
                self.pick_alive(PriorityClass::Interactive, prompt_len);
            let Some(j) = picked else { continue };
            if j == i {
                continue; // no healthy peer — hedging is pointless
            }
            let mut dup = self.requests[idx].clone();
            dup.id = HEDGE_BASE + id;
            // The duplicate "arrives" when the hedge fires; its TTFT
            // measures the recovery, not the original's queueing.
            dup.arrived_at = self.front;
            self.hedged_ever.insert(id);
            self.hedges.insert(id, Hedge { orig_rep: i, dup_rep: j });
            let SimReplica { sched, clock, .. } = &mut self.reps[j];
            clock.sleep_until(dup.arrived_at);
            sched.submit(dup);
            self.m.hedged += 1;
        }
    }

    /// After replica `stepped` advanced, settle any hedge it is a side
    /// of: the first side past its first token (or already finished)
    /// wins and the other is cancelled. Ids are visited in order so the
    /// resolution is deterministic.
    fn resolve_hedges(&mut self, stepped: usize) {
        if self.hedges.is_empty() {
            return;
        }
        let mut ids: Vec<RequestId> = self
            .hedges
            .iter()
            .filter(|(_, h)| {
                h.orig_rep == stepped || h.dup_rep == stepped
            })
            .map(|(&id, _)| id)
            .collect();
        ids.sort_unstable();
        for id in ids {
            let Some(&h) = self.hedges.get(&id) else { continue };
            let dup_id = HEDGE_BASE + id;
            // `Some(true)` = still before its first token; anything
            // else means that side produced (streamed or finished).
            let orig_waiting =
                self.reps[h.orig_rep].sched.prompt_intact(id);
            let dup_waiting =
                self.reps[h.dup_rep].sched.prompt_intact(dup_id);
            if orig_waiting != Some(true) {
                self.suppress(h.dup_rep, dup_id);
                self.hedges.remove(&id);
            } else if dup_waiting != Some(true) {
                self.suppress(h.orig_rep, id);
                self.m.hedge_wins += 1;
                self.hedges.remove(&id);
            }
        }
    }

    /// Cancel the losing side of a resolved hedge (idempotent: the
    /// loser may already have finished, which costs duplicate work but
    /// loses nothing).
    fn suppress(&mut self, rep: usize, id: RequestId) {
        let SimReplica { sched, engine, clock } = &mut self.reps[rep];
        if sched.cancel(engine, id, clock.now()) {
            self.m.duplicates_suppressed += 1;
        }
    }

    /// Index-aligned loads with the health overlay the router consumes;
    /// a crashed replica reads as draining so even the degraded-mode
    /// fallback never routes to it.
    fn loads(&self) -> Vec<ReplicaLoad> {
        let mut loads: Vec<ReplicaLoad> =
            self.reps.iter().map(|r| r.load()).collect();
        for (i, l) in loads.iter_mut().enumerate() {
            l.health = self.health.state(i);
            if self.crashed[i] {
                l.draining = true;
            }
        }
        loads
    }

    /// Route-pick a live replica for a request, honouring health; when
    /// every survivor is unhealthy, retry health-blind (degraded mode,
    /// mirroring `ReplicaSet::submit_routed`). `None` only when no
    /// replica survives at all.
    fn pick_alive(&mut self, class: PriorityClass, prompt_len: usize)
                  -> Option<usize> {
        let loads = self.loads();
        let key = RouteKey::new(class, prompt_len);
        let pick = self.route.pick(key, &loads, self.rr);
        self.rr += 1;
        if pick.is_some() {
            return pick;
        }
        let mut blind = loads;
        for (i, l) in blind.iter_mut().enumerate() {
            if !self.crashed[i] {
                l.health = Health::Healthy;
            }
        }
        let pick = self.route.pick(key, &blind, self.rr);
        self.rr += 1;
        pick.or_else(|| (0..self.reps.len()).find(|&i| !self.crashed[i]))
    }

    /// Dispatch the next arrival (the chaos twin of [`route_one`]).
    fn route_next(&mut self) {
        let mut req = self.requests[self.next].clone();
        self.next += 1;
        match self.pick_alive(req.class, req.prompt_len as usize) {
            Some(i) => {
                req.arrived_at = req.arrived_at.max(0.0);
                self.assigned.insert(req.id, i);
                let SimReplica { sched, clock, .. } = &mut self.reps[i];
                clock.sleep_until(req.arrived_at);
                sched.submit(req);
            }
            None => self.m.lost += 1,
        }
    }

    /// Re-home one prompt-intact request extracted from a crashed
    /// replica. Requests covered by a live hedge duplicate ride the
    /// duplicate instead of re-submitting (and a dead duplicate simply
    /// dissolves its pair).
    fn reroute(&mut self, mut req: Request) {
        if req.id >= HEDGE_BASE {
            self.hedges.remove(&(req.id - HEDGE_BASE));
            return;
        }
        if self.hedges.remove(&req.id).is_some() {
            self.m.hedge_wins += 1;
            return;
        }
        req.arrived_at = req.arrived_at.max(0.0);
        match self.pick_alive(req.class, req.prompt_len as usize) {
            Some(j) => {
                self.assigned.insert(req.id, j);
                let SimReplica { sched, clock, .. } = &mut self.reps[j];
                clock.sleep_until(req.arrived_at);
                sched.submit(req);
                self.m.rerouted += 1;
            }
            None => self.m.lost += 1,
        }
    }

    /// When the only remaining work sits behind a partition, the heal
    /// time the front must jump to (else the loop would end and strand
    /// it).
    fn stalled_heal_time(&self) -> Option<f64> {
        let stalled = (0..self.reps.len()).any(|i| {
            self.partitioned[i] && self.reps[i].sched.has_work()
        });
        if !stalled {
            return None;
        }
        self.events[self.next_event..]
            .iter()
            .filter(|(_, e)| {
                matches!(e, ChaosEvent::PartitionEnd(i)
                         if self.partitioned[*i])
            })
            .map(|(t, _)| *t)
            .min_by(|a, b| a.total_cmp(b))
    }
}

/// [`run_replica_sim`] under injected faults: the same virtual-time
/// replica co-simulation, plus a fault schedule ([`FaultPlan`]), the
/// [`HealthTracker`] driving routing exclusion of `Suspect`/`Down`
/// replicas, crash re-routing (prompt-intact requests re-home, streamed
/// ones end with a typed terminal record — never a hang), partition
/// stall/heal, and first-token-wins hedging for interactive requests on
/// suspect replicas. Fully deterministic for a fixed workload seed —
/// the chaos regression base behind `dynabatch chaos`. With an empty
/// plan and a quiet detector (a suspect factor high enough that clean
/// p95 spread never trips it) the run routes exactly like
/// [`run_replica_sim`], which the no-fault anchor test pins.
pub fn run_chaos_sim(scenario: &SimScenario, n_replicas: usize,
                     route: &RoutePolicy, plan: &FaultPlan)
                     -> Result<ChaosMetrics> {
    if n_replicas == 0 {
        bail!("run_chaos_sim needs at least one replica");
    }
    route.validate(n_replicas)?;
    plan.validate(n_replicas)?;
    let reps: Vec<SimReplica> = (0..n_replicas)
        .map(|_| {
            let mut sched = Scheduler::new(
                scenario.sched.clone(),
                scenario.eta_tokens(),
                scenario.swap_tokens,
                scenario.workload.prompt_mean(),
                scenario.workload.output.mean(),
            );
            sched.retain_full_traces();
            sched.telemetry.set_prior_variances(
                scenario.workload.prompt_variance(),
                scenario.workload.output.variance(),
            );
            SimReplica {
                sched,
                engine: SimEngine::new(&scenario.model,
                                       &scenario.hardware),
                clock: VirtualClock::new(),
            }
        })
        .collect();
    let mut requests = scenario.workload.generate();
    assign_classes(&mut requests, plan.mix);
    let by_index: HashMap<RequestId, usize> = requests
        .iter()
        .enumerate()
        .map(|(i, r)| (r.id, i))
        .collect();
    let mut sim = ChaosSim {
        reps,
        requests,
        next: 0,
        route,
        rr: 0,
        health: HealthTracker::new(n_replicas, plan.health),
        crashed: vec![false; n_replicas],
        partitioned: vec![false; n_replicas],
        events: plan.events(),
        next_event: 0,
        front: 0.0,
        next_observe: plan.observe_interval,
        observe_interval: plan.observe_interval,
        hedging: plan.hedging,
        by_index,
        assigned: HashMap::new(),
        hedges: HashMap::new(),
        hedged_ever: HashSet::new(),
        m: ChaosCounters::default(),
    };
    let max_steps =
        (sim.requests.len() as u64 * 4096).max(1_000_000);
    let mut steps = 0u64;
    loop {
        // The steppable replica (work, not crashed, not partitioned)
        // with the earliest clock steps next.
        let mut active: Option<usize> = None;
        for (i, r) in sim.reps.iter().enumerate() {
            if sim.crashed[i] || sim.partitioned[i]
                || !r.sched.has_work()
            {
                continue;
            }
            let earlier = match active {
                None => true,
                Some(b) => r.clock.now() < sim.reps[b].clock.now(),
            };
            if earlier {
                active = Some(i);
            }
        }
        match active {
            Some(i) => {
                let now = sim.reps[i].clock.now();
                sim.advance_front(now);
                if sim.crashed[i] || sim.partitioned[i] {
                    continue; // a fault just hit the stepping replica
                }
                if sim.next < sim.requests.len()
                    && sim.requests[sim.next].arrived_at <= now
                {
                    // Dispatch everything the time front has reached,
                    // then re-pick — routing may wake an earlier clock.
                    while sim.next < sim.requests.len()
                        && sim.requests[sim.next].arrived_at <= now
                    {
                        sim.route_next();
                    }
                    continue;
                }
                let next_arrival =
                    sim.requests.get(sim.next).map(|r| r.arrived_at);
                let SimReplica { sched, engine, clock } =
                    &mut sim.reps[i];
                match sched.step(engine, now)? {
                    Some(elapsed) => clock.advance(elapsed),
                    None => {
                        // Work exists but nothing runnable: advance to
                        // the next event.
                        match next_arrival {
                            Some(t) => {
                                clock.sleep_until(t.max(now + 1e-3));
                            }
                            None => clock.advance(1e-3),
                        }
                    }
                }
                sim.resolve_hedges(i);
                steps += 1;
                if steps >= max_steps {
                    break;
                }
            }
            None => {
                if sim.next < sim.requests.len() {
                    // Every steppable replica idle: the front jumps to
                    // the arrival (pending faults fire in the gap).
                    let t = sim.requests[sim.next].arrived_at;
                    sim.advance_front(t);
                    sim.route_next();
                    continue;
                }
                // Arrivals done: only partitioned backlogs can remain —
                // jump the front to the earliest heal and drain them.
                match sim.stalled_heal_time() {
                    Some(t) => sim.advance_front(t),
                    None => break,
                }
            }
        }
    }

    // The zero-loss ledger: every accepted original id must show a
    // terminal record somewhere (a winning hedge duplicate's terminal
    // counts for its original).
    let mut terminal: HashSet<RequestId> = HashSet::new();
    for r in &sim.reps {
        for req in r.sched.finished() {
            let id = if req.id >= HEDGE_BASE {
                req.id - HEDGE_BASE
            } else {
                req.id
            };
            terminal.insert(id);
        }
    }
    let unaccounted = sim
        .assigned
        .keys()
        .filter(|id| !terminal.contains(id))
        .count() as u64;
    let lost = sim.m.lost + unaccounted;

    // Pre/during/post fault-phase latency percentiles, bucketed by
    // arrival time against the plan's activity envelope.
    let (env_start, env_end) = plan.envelope();
    let mut ttft_by_phase: [Vec<f64>; 3] =
        std::array::from_fn(|_| Vec::new());
    let mut e2e_by_phase: [Vec<f64>; 3] =
        std::array::from_fn(|_| Vec::new());
    for r in &sim.reps {
        for req in r.sched.finished() {
            let bucket = if req.arrived_at < env_start {
                0
            } else if req.arrived_at < env_end {
                1
            } else {
                2
            };
            if let Some(t) = req.ttft() {
                ttft_by_phase[bucket].push(t);
            }
            if let Some(e) = req.e2e_latency() {
                e2e_by_phase[bucket].push(e);
            }
        }
    }
    let mut phase_ttft_p95 = [0.0f64; 3];
    let mut phase_e2e_p95 = [0.0f64; 3];
    for ((t, e), (tv, ev)) in phase_ttft_p95
        .iter_mut()
        .zip(phase_e2e_p95.iter_mut())
        .zip(ttft_by_phase.iter_mut().zip(e2e_by_phase.iter_mut()))
    {
        *t = percentile_of(tv, 95.0);
        *e = percentile_of(ev, 95.0);
    }

    let sims: Vec<&SimReplica> = sim.reps.iter().collect();
    let set = fold_replica_set(&sims, scenario, route.label());
    Ok(ChaosMetrics {
        faults_injected: plan.faults.len(),
        crashes: sim.m.crashes,
        partitions: sim.m.partitions,
        suspected: sim.m.suspected,
        recovered: sim.m.recovered,
        lost,
        failed: set.aggregate.failed,
        rerouted: sim.m.rerouted,
        hedged: sim.m.hedged,
        hedge_wins: sim.m.hedge_wins,
        duplicates_suppressed: sim.m.duplicates_suppressed,
        phase_ttft_p95,
        phase_e2e_p95,
        set,
    })
}

/// A fleet co-simulation scenario: the base scenario plus the fleet
/// composition and control policy (see [`run_fleet_sim`]).
#[derive(Debug, Clone)]
pub struct FleetScenario {
    pub base: SimScenario,
    /// Profiles of the replicas live at t = 0.
    pub initial: Vec<ReplicaProfile>,
    /// Profiles the controller may spawn mid-run (the provisioning
    /// catalogue). An autoscaler spawns the cheapest of pool+initial;
    /// a spawn directive for a name not in the pool falls back to the
    /// directive's own profile.
    pub pool: Vec<ReplicaProfile>,
    pub route: RoutePolicy,
    pub policy: FleetPolicyKind,
    /// Traffic mix for [`assign_classes`], `[interactive, standard,
    /// batch]`.
    pub mix: [f64; PriorityClass::COUNT],
}

/// One profiled replica of the fleet co-simulation.
struct FleetReplica {
    rep: SimReplica,
    profile: ReplicaProfile,
    spawned_at: f64,
    /// Set when the controller retired it: it stops taking new routes
    /// and drains its in-flight work to completion (zero-loss).
    retired_at: Option<f64>,
}

impl FleetReplica {
    fn load(&self) -> ReplicaLoad {
        let mut l = self.rep.load();
        l.decode_speed = self.profile.decode_speed;
        l.cost_unit = self.profile.cost_unit;
        l.draining = self.retired_at.is_some();
        l
    }
}

/// Build one profiled sim replica at virtual time `at`: η scaled by the
/// profile's `kv_scale` and the engine timing by its speed factors —
/// the same deployment rules [`crate::service::ServiceBuilder`] applies
/// on the live path. A neutral profile takes the exact profile-free
/// code path (bit-identical to [`run_replica_sim`]'s replicas).
fn mk_fleet_replica(scenario: &SimScenario, profile: &ReplicaProfile,
                    at: f64) -> FleetReplica {
    let eta = ((scenario.eta_tokens() as f64) * profile.kv_scale).round()
        as u64;
    let mut sched = Scheduler::new(
        scenario.sched.clone(),
        eta,
        scenario.swap_tokens,
        scenario.workload.prompt_mean(),
        scenario.workload.output.mean(),
    );
    sched.retain_full_traces();
    sched.telemetry.set_prior_variances(
        scenario.workload.prompt_variance(),
        scenario.workload.output.variance(),
    );
    let engine = if profile.is_neutral() {
        SimEngine::new(&scenario.model, &scenario.hardware)
    } else {
        SimEngine::with_profile(&scenario.model, &scenario.hardware,
                                profile)
    };
    let mut clock = VirtualClock::new();
    clock.sleep_until(at);
    FleetReplica {
        rep: SimReplica { sched, engine, clock },
        profile: profile.clone(),
        spawned_at: at,
        retired_at: None,
    }
}

/// The controller's view at virtual time `now`: index-aligned loads and
/// the worst-live-replica per-class TTFT p95.
fn fleet_observe(reps: &[FleetReplica], now: f64) -> FleetObservation {
    let loads: Vec<ReplicaLoad> = reps.iter().map(|r| r.load()).collect();
    let mut ttft = [0.0f64; PriorityClass::COUNT];
    for r in reps.iter().filter(|r| r.retired_at.is_none()) {
        for (rank, t) in ttft.iter_mut().enumerate() {
            *t = t.max(r.rep.sched.telemetry.ttft_class_p(rank, 95.0));
        }
    }
    FleetObservation { now, loads, class_ttft_p95: ttft }
}

/// The fleet co-simulation's mutable state: replicas, router, the
/// controller and its decision clock, and the directive log.
struct FleetSim<'a> {
    fs: &'a FleetScenario,
    reps: Vec<FleetReplica>,
    route: RoutePolicy,
    controller: Option<Box<dyn FleetController>>,
    interval: f64,
    next_decide: f64,
    /// Monotone virtual-time front: the max time any replica or arrival
    /// has reached — what the controller's decision clock follows.
    front: f64,
    directives: Vec<String>,
    n_spawned: usize,
    n_retired: usize,
}

impl FleetSim<'_> {
    /// Advance the time front and run every controller tick it crossed.
    fn advance_front(&mut self, t: f64) {
        if t > self.front {
            self.front = t;
        }
        if self.controller.is_none() || self.interval <= 0.0 {
            return;
        }
        while self.next_decide <= self.front {
            let at = self.next_decide;
            self.next_decide += self.interval;
            // Take the controller out so deciding (needs &mut it) and
            // executing (needs &mut the replicas) don't fight.
            let Some(mut c) = self.controller.take() else { return };
            let obs = fleet_observe(&self.reps, at);
            let d = c.decide(&obs);
            self.controller = Some(c);
            if d == FleetDirective::Hold {
                continue;
            }
            let applied = self.execute(&d, at);
            self.directives.push(format!(
                "t={at:.2} {}{}",
                d.label(),
                if applied { "" } else { " (noop)" }
            ));
        }
    }

    fn execute(&mut self, d: &FleetDirective, at: f64) -> bool {
        match d {
            FleetDirective::Hold => true,
            FleetDirective::Spawn { profile } => {
                let p = self
                    .fs
                    .pool
                    .iter()
                    .find(|q| q.name == profile.name)
                    .unwrap_or(profile);
                self.reps.push(mk_fleet_replica(&self.fs.base, p, at));
                self.n_spawned += 1;
                true
            }
            FleetDirective::Retire { replica } => {
                let ok = *replica < self.reps.len()
                    && self.reps[*replica].retired_at.is_none();
                if ok {
                    self.reps[*replica].retired_at = Some(at);
                    self.n_retired += 1;
                }
                ok
            }
            // The sim owns its router, so repinning applies directly.
            FleetDirective::Repin { route } => {
                self.route = route.clone();
                true
            }
        }
    }

    /// Route the next arrival; a retired replica is skipped by the
    /// router (its load reads as draining).
    fn route_one(&mut self, requests: &[Request], next: &mut usize,
                 rr: &mut usize) -> Result<()> {
        let loads: Vec<ReplicaLoad> =
            self.reps.iter().map(|r| r.load()).collect();
        let req = &requests[*next];
        let key = RouteKey::new(req.class, req.prompt_len as usize);
        let i = match self.route.pick(key, &loads, *rr) {
            Some(i) => i,
            None => match self
                .reps
                .iter()
                .position(|r| r.retired_at.is_none())
            {
                Some(i) => i,
                None => bail!("fleet sim has no live replica to route to"),
            },
        };
        *rr += 1;
        let mut req = req.clone();
        req.arrived_at = req.arrived_at.max(0.0);
        self.reps[i].rep.clock.sleep_until(req.arrived_at);
        self.reps[i].rep.sched.submit(req);
        *next += 1;
        Ok(())
    }
}

/// [`run_replica_sim`] generalized to a controlled heterogeneous fleet:
/// replicas deployed under [`ReplicaProfile`]s (η scaled by `kv_scale`,
/// engine timing by the speed factors), arrivals dispatched by the
/// scenario's route policy over profile-aware loads, and the fleet
/// policy's controller ticked on the monotone virtual-time front —
/// spawns add replicas mid-run (clock pulled to the spawn time),
/// retires drain them zero-loss. The run is priced in cost units:
/// replica-seconds × profile `cost_unit`, retired replicas billed to
/// drain completion, live ones to the fleet makespan. Fully
/// deterministic for a fixed workload seed.
pub fn run_fleet_sim(fs: &FleetScenario) -> Result<FleetMetrics> {
    let mut requests = fs.base.workload.generate();
    assign_classes(&mut requests, fs.mix);
    run_fleet_sim_with_requests(fs, requests)
}

/// [`run_fleet_sim`] over an explicit request list (classes already
/// assigned) — the hook for composed populations such as a burst head
/// with a long sparse tail.
pub fn run_fleet_sim_with_requests(fs: &FleetScenario,
                                   mut requests: Vec<Request>)
                                   -> Result<FleetMetrics> {
    if fs.initial.is_empty() {
        bail!("fleet sim needs at least one initial replica");
    }
    for p in fs.initial.iter().chain(&fs.pool) {
        p.validate()?;
    }
    fs.route.validate(fs.initial.len())?;
    fs.policy.validate()?;
    // What an autoscaler brings up: the cheapest profile on offer —
    // burst capacity should cost as little as possible.
    let spawn_choice = fs
        .pool
        .iter()
        .chain(&fs.initial)
        .min_by(|a, b| a.cost_unit.total_cmp(&b.cost_unit))
        .cloned()
        .unwrap_or_else(ReplicaProfile::baseline);
    let interval = match &fs.policy {
        FleetPolicyKind::Autoscale(cfg) => cfg.decide_interval,
        FleetPolicyKind::Manual => 0.0,
    };
    let mut sim = FleetSim {
        fs,
        reps: fs
            .initial
            .iter()
            .map(|p| mk_fleet_replica(&fs.base, p, 0.0))
            .collect(),
        route: fs.route.clone(),
        controller: build_fleet_controller(&fs.policy, &spawn_choice)?,
        interval,
        next_decide: interval,
        front: 0.0,
        directives: Vec::new(),
        n_spawned: 0,
        n_retired: 0,
    };
    requests.sort_by(|a, b| a.arrived_at.total_cmp(&b.arrived_at));
    let mut next = 0usize;
    let mut rr = 0usize;
    let max_steps = (requests.len() as u64 * 4096).max(1_000_000);
    let mut steps = 0u64;
    loop {
        // The replica with work and the earliest clock steps next
        // (retired replicas keep stepping — that is the drain).
        let mut active: Option<usize> = None;
        for (i, r) in sim.reps.iter().enumerate() {
            if !r.rep.sched.has_work() {
                continue;
            }
            let earlier = match active {
                None => true,
                Some(b) => {
                    r.rep.clock.now() < sim.reps[b].rep.clock.now()
                }
            };
            if earlier {
                active = Some(i);
            }
        }
        match active {
            Some(i) => {
                let now = sim.reps[i].rep.clock.now();
                sim.advance_front(now);
                if next < requests.len()
                    && requests[next].arrived_at <= now
                {
                    // Dispatch everything the time front has reached,
                    // then re-pick — routing may wake an earlier clock.
                    while next < requests.len()
                        && requests[next].arrived_at <= now
                    {
                        sim.route_one(&requests, &mut next, &mut rr)?;
                    }
                    continue;
                }
                let r = &mut sim.reps[i];
                match r.rep.sched.step(&mut r.rep.engine, now)? {
                    Some(elapsed) => r.rep.clock.advance(elapsed),
                    None => {
                        // Work exists but nothing runnable: advance to
                        // the next event.
                        if next < requests.len() {
                            let t = requests[next].arrived_at;
                            r.rep.clock.sleep_until(t.max(now + 1e-3));
                        } else {
                            r.rep.clock.advance(1e-3);
                        }
                    }
                }
                steps += 1;
                if steps >= max_steps {
                    break;
                }
            }
            None => {
                if next >= requests.len() {
                    break; // drained everywhere
                }
                // Every replica idle: the front jumps to the arrival
                // (pending controller ticks fire in the gap first).
                sim.advance_front(requests[next].arrived_at);
                sim.route_one(&requests, &mut next, &mut rr)?;
            }
        }
    }

    let sims: Vec<&SimReplica> =
        sim.reps.iter().map(|r| &r.rep).collect();
    let set = fold_replica_set(&sims, &fs.base, sim.route.label());
    // Price the run: a retired replica bills to the later of its drain
    // completion and the retire decision; a live one to the fleet
    // makespan (provisioned capacity costs while it is on call).
    let agg_makespan = set.aggregate.makespan;
    let mut cost_units = 0.0f64;
    for r in &sim.reps {
        let end = match r.retired_at {
            Some(at) => r.rep.clock.now().max(at),
            None => agg_makespan.max(r.spawned_at),
        };
        cost_units += (end - r.spawned_at) * r.profile.cost_unit;
    }
    Ok(FleetMetrics {
        controller: fs.policy.label(),
        profiles: sim
            .reps
            .iter()
            .map(|r| r.profile.name.clone())
            .collect(),
        n_spawned: sim.n_spawned,
        n_retired: sim.n_retired,
        cost_units,
        directives: sim.directives,
        set,
    })
}

/// One row of the cost/SLA frontier swept by [`fleet_frontier`].
#[derive(Debug, Clone)]
pub struct FleetFrontierRow {
    pub rate: f64,
    /// `static:<profile>*N` for the homogeneous references, the fleet
    /// scenario's own label for the controlled fleet.
    pub label: String,
    pub cost_units: f64,
    /// Aggregate interactive TTFT p95 over the run (seconds).
    pub ttft_p95_interactive: f64,
    /// Interactive TTFT p95 within target, every request finished,
    /// nothing shed.
    pub meets: bool,
    /// Cheapest configuration meeting the target at this rate.
    pub cheapest_meeting: bool,
    pub fleet: FleetMetrics,
}

impl FleetFrontierRow {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("rate_qps", Json::Num(self.rate)),
            ("label", Json::from(self.label.clone())),
            ("cost_units", Json::Num(self.cost_units)),
            (
                "ttft_p95_interactive_s",
                Json::Num(self.ttft_p95_interactive),
            ),
            ("meets", Json::from(self.meets)),
            ("cheapest_meeting", Json::from(self.cheapest_meeting)),
            ("fleet", self.fleet.to_json()),
        ])
    }
}

/// Sweep arrival rate × fleet configuration into the cost/SLA frontier
/// behind `dynabatch fleet`: at each Poisson rate the same class-mixed
/// workload runs against static homogeneous baseline fleets of
/// 1..=`max_static` replicas and against the scenario's own (typically
/// heterogeneous, autoscaled) fleet; each row reports cost units and
/// whether the interactive TTFT p95 target was met, and the cheapest
/// meeting row per rate is flagged. Fixed seeds → bit-identical tables.
pub fn fleet_frontier(fs: &FleetScenario, rates: &[f64],
                      ttft_target: f64, max_static: usize)
                      -> Result<Vec<FleetFrontierRow>> {
    if rates.is_empty() || max_static == 0 {
        bail!("fleet_frontier needs at least one rate and one static \
               fleet size");
    }
    if ttft_target <= 0.0 {
        bail!("fleet_frontier needs a positive interactive TTFT target");
    }
    let mut rows = Vec::new();
    for &rate in rates {
        let mut base = fs.base.clone();
        base.workload =
            base.workload.with_arrival(Arrival::Poisson { rate });
        let mut requests = base.workload.generate();
        assign_classes(&mut requests, fs.mix);
        let n_total = requests.len();
        let row = |label: String, fleet: FleetMetrics| {
            let ttft = fleet.set.aggregate.per_class
                [PriorityClass::Interactive.rank()]
            .ttft_p95;
            let meets = ttft <= ttft_target
                && fleet.set.aggregate.n_finished == n_total
                && fleet.set.aggregate.shed == 0;
            FleetFrontierRow {
                rate,
                label,
                cost_units: fleet.cost_units,
                ttft_p95_interactive: ttft,
                meets,
                cheapest_meeting: false,
                fleet,
            }
        };
        let mut rate_rows = Vec::new();
        let reference = ReplicaProfile::baseline();
        for n in 1..=max_static {
            let static_fs = FleetScenario {
                base: base.clone(),
                initial: vec![reference.clone(); n],
                pool: Vec::new(),
                route: fs.route.clone(),
                policy: FleetPolicyKind::Manual,
                mix: fs.mix,
            };
            let m =
                run_fleet_sim_with_requests(&static_fs, requests.clone())?;
            rate_rows
                .push(row(format!("static:{}*{n}", reference.name), m));
        }
        let auto_fs = FleetScenario { base, ..fs.clone() };
        let m = run_fleet_sim_with_requests(&auto_fs, requests.clone())?;
        let names: Vec<&str> =
            fs.initial.iter().map(|p| p.name.as_str()).collect();
        rate_rows.push(row(format!("fleet:{}", names.join("+")), m));
        if let Some(best) = rate_rows
            .iter()
            .enumerate()
            .filter(|(_, r)| r.meets)
            .min_by(|(ai, a), (bi, b)| {
                a.cost_units.total_cmp(&b.cost_units).then(ai.cmp(bi))
            })
            .map(|(i, _)| i)
        {
            rate_rows[best].cheapest_meeting = true;
        }
        rows.extend(rate_rows);
    }
    Ok(rows)
}

/// One cell of the policy-switch sweep table (see [`switch_sweep`]).
#[derive(Debug, Clone)]
pub struct SwitchSweepRow {
    pub switch_at: f64,
    /// Extra requests injected all-at-once at the spike time.
    pub spike_requests: usize,
    /// The run that stays on the scenario's starting policy.
    pub baseline: RunMetrics,
    /// The run that hot-swaps to the target policy at `switch_at`.
    pub switched: RunMetrics,
}

impl SwitchSweepRow {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("switch_at_s", Json::Num(self.switch_at)),
            ("spike_requests", Json::from(self.spike_requests)),
            ("baseline", self.baseline.to_json()),
            ("switched", self.switched.to_json()),
        ])
    }
}

/// Sweep the policy-switch scenario over switch time × spike magnitude:
/// for each spike size, the scenario's base workload is augmented with
/// that many extra requests arriving all at once at `spike_at` (lengths
/// drawn from the base distributions under a seed derived from the
/// workload seed), then run once without switching and once hot-swapping
/// to `to` at each switch time. Every cell is deterministic for fixed
/// seeds — the regression table behind `dynabatch switch --sweep`.
pub fn switch_sweep(scenario: &SimScenario, to: PolicyKind,
                    switch_ats: &[f64], spike_at: f64,
                    spike_magnitudes: &[usize])
                    -> Result<Vec<SwitchSweepRow>> {
    if switch_ats.is_empty() || spike_magnitudes.is_empty() {
        bail!("switch_sweep needs at least one switch time and one \
               spike magnitude");
    }
    let base = scenario.workload.generate();
    let mut rows = Vec::new();
    for &spike_n in spike_magnitudes {
        let mut requests = base.clone();
        if spike_n > 0 {
            let spike_w = Workload {
                name: format!("{}-spike{spike_n}", scenario.workload.name),
                arrival: Arrival::AllAtOnce,
                prompt: scenario.workload.prompt.clone(),
                output: scenario.workload.output.clone(),
                n_requests: spike_n,
                seed: scenario
                    .workload
                    .seed
                    .wrapping_mul(0x9e37_79b9)
                    .wrapping_add(spike_n as u64),
                prefix: None,
                length_mix: None,
            };
            let base_n = requests.len() as u64;
            let mut spike = spike_w.generate();
            for (j, r) in spike.iter_mut().enumerate() {
                r.id = base_n + j as u64; // keep ids disjoint
                r.arrived_at = spike_at;
            }
            requests.extend(spike);
        }
        let baseline =
            run_sim_with_requests(scenario, requests.clone(), &[])?;
        for &at in switch_ats {
            let switched = run_sim_with_requests(
                scenario,
                requests.clone(),
                &[PolicySwitch { at, to: to.clone() }],
            )?;
            rows.push(SwitchSweepRow {
                switch_at: at,
                spike_requests: spike_n,
                baseline: baseline.clone(),
                switched,
            });
        }
    }
    Ok(rows)
}

/// Deterministically assign priority classes to a request list by the
/// traffic mix `[interactive, standard, batch]` (fractions, normalized
/// over their sum). The assignment hashes the request index — fixed for
/// a fixed list, independent of arrival order, and interleaved rather
/// than blocked, so every window of the run carries the mix.
pub fn assign_classes(requests: &mut [Request],
                      mix: [f64; PriorityClass::COUNT]) {
    let total: f64 = mix.iter().sum();
    if total <= 0.0 {
        return;
    }
    for (i, r) in requests.iter_mut().enumerate() {
        // splitmix-style index hash → uniform u in [0, 1).
        let h = (i as u64)
            .wrapping_mul(0x9e37_79b9_7f4a_7c15)
            .rotate_left(31)
            .wrapping_mul(0xbf58_476d_1ce4_e5b9);
        let u = (h >> 11) as f64 / (1u64 << 53) as f64 * total;
        r.class = if u < mix[0] {
            PriorityClass::Interactive
        } else if u < mix[0] + mix[1] {
            PriorityClass::Standard
        } else {
            PriorityClass::Batch
        };
    }
}

/// One row of the per-class SLA sweep (see [`sla_sweep`]).
#[derive(Debug, Clone)]
pub struct SlaSweepRow {
    /// `baseline(<policy>)` for row 0, the `per-class-sla(...)` label
    /// for target rows.
    pub label: String,
    pub metrics: RunMetrics,
}

impl SlaSweepRow {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::from(self.label.clone())),
            ("metrics", self.metrics.to_json()),
        ])
    }
}

/// The per-class SLA sweep behind `dynabatch sla`: the scenario's
/// workload gets classes assigned by `mix`, then runs once on the
/// scenario's base policy (the unconstrained baseline) and once per
/// target set under `min(<base policy>, per-class-sla(<targets>))` — the
/// paper's combined-controller shape with Algorithm 2 split per class.
/// Fixed seeds → bit-identical rows; the regression property (tightening
/// only the interactive target keeps aggregate throughput within the
/// capacity trade-off) is asserted in this module's tests.
pub fn sla_sweep(scenario: &SimScenario,
                 target_sets: &[[Option<f64>; PriorityClass::COUNT]],
                 mix: [f64; PriorityClass::COUNT])
                 -> Result<Vec<SlaSweepRow>> {
    let mut requests = scenario.workload.generate();
    assign_classes(&mut requests, mix);
    let mut rows = vec![SlaSweepRow {
        label: format!("baseline({})", scenario.sched.policy.label()),
        metrics: run_sim_with_requests(scenario, requests.clone(), &[])?,
    }];
    for targets in target_sets {
        let kind = PolicyKind::PerClassSla(*targets);
        kind.validate()?;
        let mut s = scenario.clone();
        s.sched.policy = PolicyKind::Min(vec![
            scenario.sched.policy.clone(),
            kind.clone(),
        ]);
        rows.push(SlaSweepRow {
            label: kind.label(),
            metrics: run_sim_with_requests(&s, requests.clone(), &[])?,
        });
    }
    Ok(rows)
}

/// Outcome of a capacity search (Table II / Fig. 4).
#[derive(Debug, Clone)]
pub struct CapacityResult {
    /// Max sustainable request rate (qps) meeting the SLA.
    pub capacity_qps: f64,
    /// Metrics at the capacity point.
    pub at_capacity: RunMetrics,
}

/// Binary-search the highest Poisson rate whose run meets the SLA at
/// percentile `pct` (and finishes every request). `probe_requests` bounds
/// run length during the search.
pub fn capacity_search(
    scenario: &SimScenario,
    d_sla: f64,
    eps_d: f64,
    pct: f64,
    probe_requests: usize,
    resolution: f64,
) -> Result<CapacityResult> {
    // Probe size scales with the offered rate so the arrival span (≥20 s
    // simulated) dominates per-request residence time — otherwise a short
    // burst drains within the grace window and overload goes undetected.
    let n_at = |rate: f64| probe_requests.max((rate * 20.0).ceil() as usize);
    let probe = |rate: f64| -> Result<RunMetrics> {
        let mut s = scenario.clone();
        s.workload = s
            .workload
            .with_arrival(Arrival::Poisson { rate });
        s.workload.n_requests = n_at(rate);
        run_sim(&s)
    };
    // Meeting the TBT SLA is necessary but not sufficient: a TBT-gating
    // policy could claim unbounded capacity by parking load in the queue.
    // Capacity additionally requires *stability*: queueing delay (TTFT)
    // bounded and the makespan close to the arrival span.
    let ttft_slo = (10.0 * d_sla).max(2.0);
    let ok = |m: &RunMetrics, rate: f64| {
        let span = n_at(rate) as f64 / rate;
        m.meets_sla(d_sla, eps_d, pct)
            && m.n_requests >= n_at(rate)
            && m.ttft_p95 <= ttft_slo
            && m.makespan <= span * 1.15 + 2.0
    };

    // Bracket: grow until violation.
    let mut lo = 0.0f64;
    let mut lo_metrics: Option<RunMetrics> = None;
    let mut hi = 0.5f64;
    loop {
        let m = probe(hi)?;
        if ok(&m, hi) {
            lo = hi;
            lo_metrics = Some(m);
            hi *= 2.0;
            if hi > 4096.0 {
                break; // engine never violates — call that capacity
            }
        } else {
            break;
        }
    }
    // Bisect.
    while hi - lo > resolution {
        let mid = 0.5 * (lo + hi);
        let m = probe(mid)?;
        if ok(&m, mid) {
            lo = mid;
            lo_metrics = Some(m);
        } else {
            hi = mid;
        }
    }
    let at = match lo_metrics {
        Some(m) => m,
        None => probe(lo.max(resolution))?,
    };
    Ok(CapacityResult { capacity_qps: lo, at_capacity: at })
}

/// Outcome of the prefix-sharing capacity regression
/// ([`prefix_capacity`], the `dynabatch prefix` subcommand): the same
/// multi-tenant workload capacity-searched twice — prefix cache off
/// (baseline) and on (shared) — at the same SLA.
#[derive(Debug, Clone)]
pub struct PrefixCapacityResult {
    pub baseline: CapacityResult,
    pub shared: CapacityResult,
    /// `shared.capacity_qps / baseline.capacity_qps` (0.0 when the
    /// baseline sustains nothing).
    pub ratio: f64,
}

impl PrefixCapacityResult {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("baseline_qps", Json::Num(self.baseline.capacity_qps)),
            ("shared_qps", Json::Num(self.shared.capacity_qps)),
            ("ratio", Json::Num(self.ratio)),
            (
                "shared_hit_rate",
                self.shared
                    .at_capacity
                    .prefix_hit_rate
                    .map(Json::Num)
                    .unwrap_or(Json::Null),
            ),
            ("baseline", self.baseline.at_capacity.to_json()),
            ("shared", self.shared.at_capacity.to_json()),
        ])
    }
}

/// Capacity with and without prefix sharing on the scenario's
/// multi-tenant workload: two [`capacity_search`]es differing only in
/// `sched.prefix_cache`, same seed, same SLA. On memory-bound
/// shared-prefix traffic the shared run admits the tenant prefix once
/// instead of per request, so it sustains a higher rate — the
/// regression the `dynabatch prefix` scenario pins. Errors unless the
/// workload carries a [`SharedPrefixSpec`]
/// (`workload.prefix`) — without materialized prompt tokens there is
/// nothing to share and the comparison would be vacuous.
///
/// [`SharedPrefixSpec`]: crate::workload::SharedPrefixSpec
pub fn prefix_capacity(scenario: &SimScenario, d_sla: f64, eps_d: f64,
                       pct: f64, probe_requests: usize, resolution: f64)
                       -> Result<PrefixCapacityResult> {
    if scenario.workload.prefix.is_none() {
        bail!("prefix_capacity needs a multi-tenant workload \
               (workload.prefix = Some(SharedPrefixSpec {{ … }}))");
    }
    let mut base = scenario.clone();
    base.sched.prefix_cache = false;
    let mut shrd = scenario.clone();
    shrd.sched.prefix_cache = true;
    let baseline =
        capacity_search(&base, d_sla, eps_d, pct, probe_requests,
                        resolution)?;
    let shared =
        capacity_search(&shrd, d_sla, eps_d, pct, probe_requests,
                        resolution)?;
    let ratio = if baseline.capacity_qps > 0.0 {
        shared.capacity_qps / baseline.capacity_qps
    } else {
        0.0
    };
    Ok(PrefixCapacityResult { baseline, shared, ratio })
}

/// Outcome of the bucketed-batching regression ([`bucket_compare`],
/// the `dynabatch bucket` subcommand): the same long-tail workload run
/// twice under rectangular-kernel padding accounting — flat admission
/// (every prefill group padded to the step maximum) vs length-bucketed
/// admission (padded only to each bucket's ceiling).
#[derive(Debug, Clone)]
pub struct BucketCompareResult {
    /// Flat (unbucketed) run, `padded_prefill` accounting on.
    pub flat: RunMetrics,
    /// Bucketed run — same seed, same accounting, `sched.buckets` on.
    pub bucketed: RunMetrics,
    /// `bucketed.throughput / flat.throughput` (0.0 when the flat run
    /// moved nothing).
    pub ratio: f64,
}

impl BucketCompareResult {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("flat_throughput_tok_s", Json::Num(self.flat.throughput)),
            (
                "bucketed_throughput_tok_s",
                Json::Num(self.bucketed.throughput),
            ),
            ("ratio", Json::Num(self.ratio)),
            (
                "flat_padding_waste",
                self.flat
                    .padding_waste
                    .map(Json::Num)
                    .unwrap_or(Json::Null),
            ),
            (
                "bucketed_padding_waste",
                self.bucketed
                    .padding_waste
                    .map(Json::Num)
                    .unwrap_or(Json::Null),
            ),
            ("flat", self.flat.to_json()),
            ("bucketed", self.bucketed.to_json()),
        ])
    }
}

/// Throughput with and without length-bucketed admission on the
/// scenario's workload: two [`run_sim`]s differing only in
/// `sched.buckets` (the flat arm forces it to 0), both with
/// `padded_prefill` rectangular-kernel accounting on so the padding
/// cost the buckets exist to kill is actually charged. Same seed, same
/// admission (bucket quotas stay as configured — leave
/// `bucket_quota = 0` for an apples-to-apples comparison where only
/// the kernel grouping differs). Errors unless the scenario enables
/// bucketing — without `sched.buckets > 0` there is nothing to
/// compare.
pub fn bucket_compare(scenario: &SimScenario)
                      -> Result<BucketCompareResult> {
    if scenario.sched.buckets == 0 {
        bail!("bucket_compare needs sched.buckets > 0 \
               (the bucketed arm's plan)");
    }
    let mut flat = scenario.clone();
    flat.sched.buckets = 0;
    flat.sched.padded_prefill = true;
    let mut bkt = scenario.clone();
    bkt.sched.padded_prefill = true;
    let flat_m = run_sim(&flat)?;
    let bucketed = run_sim(&bkt)?;
    let ratio = if flat_m.throughput > 0.0 {
        bucketed.throughput / flat_m.throughput
    } else {
        0.0
    };
    Ok(BucketCompareResult { flat: flat_m, bucketed, ratio })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::*;
    use crate::config::{FleetConfig, PolicyKind};
    use crate::workload::{LengthDist, LengthMix};

    fn scenario(policy: PolicyKind, n: usize, arrival: Arrival)
                -> SimScenario {
        let model = pangu_7b();
        let hardware = node_for(&model);
        SimScenario {
            model,
            hardware,
            sched: SchedulerConfig { policy, ..SchedulerConfig::default() },
            workload: Workload {
                name: "test".into(),
                arrival,
                prompt: LengthDist::Fixed(128),
                output: LengthDist::Fixed(128),
                n_requests: n,
                seed: 5,
                prefix: None,
                length_mix: None,
            },
            eta_tokens_override: None,
            swap_tokens: 0,
        }
    }

    #[test]
    fn sim_run_completes_and_reports() {
        let s = scenario(PolicyKind::MemoryAware, 100, Arrival::AllAtOnce);
        let m = run_sim(&s).unwrap();
        assert_eq!(m.n_requests, 100);
        assert_eq!(m.output_tokens, 100 * 128);
        assert!(m.throughput > 0.0);
        assert!(m.makespan > 0.0);
        assert!(m.mean_batch >= 1.0);
        assert!(m.utilization.unwrap() > 0.0);
    }

    #[test]
    fn poisson_run_has_idle_gaps() {
        let s = scenario(PolicyKind::MemoryAware, 50,
                         Arrival::Poisson { rate: 0.5 });
        let m = run_sim(&s).unwrap();
        assert_eq!(m.n_requests, 50);
        // 50 requests at 0.5 qps → makespan ≈ 100 s (arrival-dominated).
        assert!(m.makespan > 50.0, "makespan={}", m.makespan);
    }

    #[test]
    fn diurnal_run_completes_deterministically() {
        // The loadgen's day/night arrival process through the same
        // virtual-time path as every other workload: nothing lost,
        // arrival-dominated makespan, bit-identical reruns.
        let s = scenario(
            PolicyKind::MemoryAware,
            80,
            Arrival::Diurnal { mean: 2.0, amplitude: 0.7, period: 10.0 },
        );
        let a = run_sim(&s).unwrap();
        let b = run_sim(&s).unwrap();
        assert_eq!(a.n_requests, 80);
        // 80 requests at mean 2 qps → ≈ 40 s of arrivals.
        assert!(a.makespan > 20.0, "makespan={}", a.makespan);
        assert_eq!(a.throughput.to_bits(), b.throughput.to_bits());
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(a.tbt_p95.to_bits(), b.tbt_p95.to_bits());
    }

    #[test]
    fn dynamic_beats_greedy_under_memory_pressure() {
        // The Table-I mechanism in miniature, in the regime where it bites
        // (the LLaMA-65B row: long, variable outputs — every recompute
        // preemption re-prefills a long context and stalls the batch).
        let model = llama_65b();
        let hardware = node_for(&model);
        let mk = |policy| SimScenario {
            model: model.clone(),
            hardware: hardware.clone(),
            sched: SchedulerConfig { policy, ..SchedulerConfig::default() },
            workload: Workload {
                name: "t1-65b-mini".into(),
                arrival: Arrival::AllAtOnce,
                prompt: LengthDist::around(68.4, 1024),
                output: LengthDist::around(344.5, 1024),
                n_requests: 300,
                seed: 5,
                prefix: None,
                length_mix: None,
            },
            eta_tokens_override: None,
            swap_tokens: 0,
        };
        let mg = run_sim(&mk(PolicyKind::StaticGreedy { max: 256 })).unwrap();
        let md = run_sim(&mk(PolicyKind::MemoryAware)).unwrap();
        assert!(mg.preemptions > 0, "greedy must thrash");
        assert!(md.preemptions <= mg.preemptions / 10,
                "Alg.1 must mostly avoid thrash: {} vs {}", md.preemptions,
                mg.preemptions);
        assert!(
            md.throughput > mg.throughput,
            "dynamic {:.0} <= static {:.0} tok/s (preempts {} vs {})",
            md.throughput,
            mg.throughput,
            md.preemptions,
            mg.preemptions
        );
    }

    #[test]
    fn run_loop_sheds_expired_deadlines_and_reports() {
        // One slot: request 0 monopolizes it for hundreds of virtual ms,
        // request 1's absolute deadline lapses while it waits, and the
        // shed shows up in the metrics.
        let model = pangu_7b();
        let hardware = node_for(&model);
        let cfg = SchedulerConfig {
            policy: PolicyKind::StaticFixed { batch: 1 },
            ..SchedulerConfig::default()
        };
        let mut sched = Scheduler::new(cfg, 100_000, 0, 64.0, 64.0);
        let mut engine = SimEngine::new(&model, &hardware);
        let mut clock = VirtualClock::new();
        let requests = vec![
            Request::new(0, 64, 400, 0.0),
            Request::new(1, 64, 8, 0.0).with_deadline(Some(0.05)),
        ];
        run_loop(&mut sched, &mut engine, &mut clock, requests, 1_000_000)
            .unwrap();
        let m = RunMetrics::compute(
            sched.controller_label(),
            sched.finished(),
            &sched.stats,
            &sched.decode_latencies.to_vec(),
            clock.now(),
            engine.utilization(),
        );
        assert_eq!(m.shed, 1);
        assert_eq!(m.n_requests, 2);
        assert_eq!(m.n_finished, 1, "only the survivor generated tokens");
        assert_eq!(m.output_tokens, 400);
    }

    #[test]
    fn mid_run_policy_switch_completes_and_reconfigures() {
        // Start on a throttled fixed batch, hot-swap to the paper's
        // combined controller mid-run: every request still finishes, the
        // reconfig is counted, and the b_t trace shows both regimes.
        let mut s = scenario(PolicyKind::StaticFixed { batch: 2 }, 120,
                             Arrival::Poisson { rate: 10.0 });
        s.sched.d_sla = Some(0.05);
        let switched = run_sim_switched(
            &s,
            &[PolicySwitch { at: 2.0, to: PolicyKind::Combined }],
        )
        .unwrap();
        assert_eq!(switched.n_finished, 120);
        assert_eq!(switched.reconfigs, 1);
        assert_eq!(switched.policy, "combined(min(alg1,alg2))");
        // The un-switched baseline stays throttled for the whole run and
        // must be strictly slower end-to-end.
        let fixed = run_sim(&s).unwrap();
        assert_eq!(fixed.reconfigs, 0);
        assert!(
            switched.makespan < fixed.makespan,
            "switching to the dynamic controller must relieve the \
             throttle: {} vs {}",
            switched.makespan,
            fixed.makespan
        );
    }

    #[test]
    fn replica_sim_single_replica_completes_like_run_sim() {
        let s = scenario(PolicyKind::MemoryAware, 80, Arrival::AllAtOnce);
        let single = run_sim(&s).unwrap();
        let set =
            run_replica_sim(&s, 1, &RoutePolicy::LeastLoaded).unwrap();
        assert_eq!(set.n_replicas, 1);
        assert_eq!(set.per_replica.len(), 1);
        assert_eq!(set.aggregate.n_requests, 80);
        assert_eq!(set.aggregate.output_tokens, single.output_tokens);
        // One replica routed through the set is the same simulation.
        assert!((set.aggregate.makespan - single.makespan).abs() < 1e-9,
                "{} vs {}", set.aggregate.makespan, single.makespan);
    }

    #[test]
    fn replica_sim_two_replicas_split_and_speed_up() {
        // Batch-bound regime: a fixed b_t throttles each replica, so a
        // second replica should nearly double aggregate throughput.
        let s = scenario(PolicyKind::StaticFixed { batch: 8 }, 200,
                         Arrival::AllAtOnce);
        let one =
            run_replica_sim(&s, 1, &RoutePolicy::LeastLoaded).unwrap();
        let two =
            run_replica_sim(&s, 2, &RoutePolicy::LeastLoaded).unwrap();
        assert_eq!(two.aggregate.n_requests, 200, "no request lost");
        assert_eq!(two.aggregate.output_tokens, one.aggregate.output_tokens);
        assert!(two.max_token_share() < 0.65,
                "least-loaded must split the load: share {}",
                two.max_token_share());
        assert!(
            two.aggregate.throughput >= 1.8 * one.aggregate.throughput,
            "2 replicas must scale: {} vs {}",
            two.aggregate.throughput,
            one.aggregate.throughput
        );
    }

    #[test]
    fn replica_sim_is_deterministic() {
        let s = scenario(PolicyKind::Combined, 60,
                         Arrival::Poisson { rate: 20.0 });
        let a = run_replica_sim(&s, 2, &RoutePolicy::LeastLoaded).unwrap();
        let b = run_replica_sim(&s, 2, &RoutePolicy::LeastLoaded).unwrap();
        assert_eq!(a.to_json().to_string(), b.to_json().to_string(),
                   "same seed → bit-identical replica-set metrics");
        assert_eq!(a.aggregate.n_requests, 60);
    }

    /// With an empty fault plan the chaos loop must be behaviourally
    /// inert: same routing, same numbers, bit-identical to
    /// [`run_replica_sim`] — the guard that keeps every pre-chaos
    /// fixed-seed anchor honest. The suspect factor is set impossibly
    /// high so the detector observes without ever firing.
    #[test]
    fn chaos_sim_without_faults_matches_replica_sim() {
        let s = scenario(PolicyKind::Combined, 60,
                         Arrival::Poisson { rate: 20.0 });
        let plain =
            run_replica_sim(&s, 2, &RoutePolicy::LeastLoaded).unwrap();
        let plan = FaultPlan {
            health: HealthPolicy {
                suspect_factor: 1e9,
                ..HealthPolicy::default()
            },
            ..FaultPlan::default()
        };
        let chaos =
            run_chaos_sim(&s, 2, &RoutePolicy::LeastLoaded, &plan)
                .unwrap();
        assert_eq!(chaos.set.to_json().to_string(),
                   plain.to_json().to_string(),
                   "an empty fault plan must be behaviourally inert");
        assert_eq!(chaos.lost, 0);
        assert_eq!(chaos.failed, 0);
        assert_eq!((chaos.rerouted, chaos.hedged), (0, 0));
        assert_eq!(chaos.faults_injected, 0);
    }

    /// The crash acceptance regression: a mid-run replica crash at
    /// steady load loses nothing — every accepted request is re-routed
    /// (prompt intact) or ends in a typed terminal error — and the
    /// interactive TTFT p95 stays within a pinned envelope of the
    /// no-fault run while the survivor absorbs the traffic. Bit-
    /// identical per seed.
    #[test]
    fn chaos_crash_loses_nothing_and_stays_in_envelope() {
        let s = scenario(PolicyKind::Combined, 100,
                         Arrival::Poisson { rate: 10.0 });
        let mix = [0.5, 0.3, 0.2];
        let quiet = FaultPlan { mix, ..FaultPlan::default() };
        let base =
            run_chaos_sim(&s, 2, &RoutePolicy::LeastLoaded, &quiet)
                .unwrap();
        let plan = FaultPlan {
            faults: vec![Fault::Crash { replica: 0, at: 2.0 }],
            mix,
            ..FaultPlan::default()
        };
        let a = run_chaos_sim(&s, 2, &RoutePolicy::LeastLoaded, &plan)
            .unwrap();
        let b = run_chaos_sim(&s, 2, &RoutePolicy::LeastLoaded, &plan)
            .unwrap();
        assert_eq!(a.to_json().to_string(), b.to_json().to_string(),
                   "same seed → bit-identical chaos metrics");
        assert_eq!(a.crashes, 1);
        assert_eq!(a.lost, 0, "zero accepted requests lost");
        // Exactly one terminal record per request: completions on the
        // survivor plus typed failures for mid-stream victims.
        assert_eq!(a.set.aggregate.n_requests, 100);
        assert!(a.rerouted + a.failed >= 1,
                "the crash must have hit in-flight work (rerouted {} \
                 failed {})", a.rerouted, a.failed);
        let rank = PriorityClass::Interactive.rank();
        let base_p95 = base.set.aggregate.per_class[rank].ttft_p95;
        let got_p95 = a.set.aggregate.per_class[rank].ttft_p95;
        assert!(got_p95 <= base_p95 * 4.0 + 1.0,
                "interactive TTFT p95 out of envelope: {got_p95} vs \
                 no-fault {base_p95}");
    }

    /// The straggler acceptance regression: a 4× slow replica is
    /// detected (p95 over the fleet median for the dwell window),
    /// excluded from routing, and the healthy replica absorbs the
    /// traffic with interactive TTFT p95 inside the envelope. Bit-
    /// identical per seed.
    #[test]
    fn chaos_straggler_detected_excluded_and_in_envelope() {
        let s = scenario(PolicyKind::Combined, 100,
                         Arrival::Poisson { rate: 10.0 });
        let mix = [0.5, 0.3, 0.2];
        let quiet = FaultPlan { mix, ..FaultPlan::default() };
        let base =
            run_chaos_sim(&s, 2, &RoutePolicy::LeastLoaded, &quiet)
                .unwrap();
        let plan = FaultPlan {
            faults: vec![Fault::Slow {
                replica: 0,
                at: 1.0,
                factor: 4.0,
                duration: 1e6,
            }],
            mix,
            ..FaultPlan::default()
        };
        let a = run_chaos_sim(&s, 2, &RoutePolicy::LeastLoaded, &plan)
            .unwrap();
        let b = run_chaos_sim(&s, 2, &RoutePolicy::LeastLoaded, &plan)
            .unwrap();
        assert_eq!(a.to_json().to_string(), b.to_json().to_string(),
                   "same seed → bit-identical chaos metrics");
        assert!(a.suspected >= 1, "4x straggler must be detected");
        assert_eq!(a.lost, 0);
        assert_eq!(a.failed, 0, "a slow replica kills nothing");
        assert!(a.set.aggregate.n_requests >= 100,
                "every original request has a terminal record");
        let rank = PriorityClass::Interactive.rank();
        let base_p95 = base.set.aggregate.per_class[rank].ttft_p95;
        let got_p95 = a.set.aggregate.per_class[rank].ttft_p95;
        assert!(got_p95 <= base_p95 * 6.0 + 1.0,
                "interactive TTFT p95 out of envelope: {got_p95} vs \
                 no-fault {base_p95}");
    }

    /// A partition is a stall, not a death: the replica freezes for the
    /// outage, takes no routes, then heals, drains its backlog, and
    /// every request ends in exactly one terminal record.
    #[test]
    fn chaos_partition_stalls_heals_and_drains_zero_loss() {
        let s = scenario(PolicyKind::Combined, 80,
                         Arrival::Poisson { rate: 10.0 });
        let plan = FaultPlan {
            faults: vec![Fault::Partition {
                replicas: vec![0],
                at: 1.0,
                duration: 2.0,
            }],
            hedging: false,
            ..FaultPlan::default()
        };
        let m = run_chaos_sim(&s, 2, &RoutePolicy::LeastLoaded, &plan)
            .unwrap();
        assert_eq!((m.partitions, m.recovered), (1, 1));
        assert_eq!(m.lost, 0);
        assert_eq!(m.failed, 0);
        assert_eq!(m.set.aggregate.n_requests, 80,
                   "exactly one terminal record per request");
        assert_eq!(m.set.aggregate.output_tokens, 80 * 128,
                   "the stalled backlog drains to full completion");
    }

    /// Hedging rescues interactive requests stranded behind a
    /// straggler: round-robin keeps feeding the slow replica until the
    /// detector fires, then its queued interactive prompts duplicate
    /// onto the healthy peer, first token wins, and losers are
    /// cancelled — nothing is lost and no hedge dangles.
    #[test]
    fn chaos_hedging_rescues_interactive_from_straggler() {
        let s = scenario(PolicyKind::Combined, 120,
                         Arrival::Poisson { rate: 16.0 });
        let plan = FaultPlan {
            faults: vec![Fault::Slow {
                replica: 0,
                at: 1.0,
                factor: 8.0,
                duration: 1e6,
            }],
            mix: [1.0, 0.0, 0.0],
            ..FaultPlan::default()
        };
        let m = run_chaos_sim(&s, 2, &RoutePolicy::RoundRobin, &plan)
            .unwrap();
        assert!(m.suspected >= 1, "8x straggler must be detected");
        assert!(m.hedged >= 1,
                "queued interactive prompts must hedge off the \
                 suspect replica");
        assert!(m.duplicates_suppressed <= m.hedged);
        assert_eq!(m.lost, 0);
        assert!(m.set.aggregate.n_requests >= 120,
                "every original request has a terminal record");
    }

    /// A manual fleet of one neutral baseline replica is the replica
    /// co-simulation: the fleet layer must add nothing to the numbers,
    /// only the cost/controller wrapper around them.
    #[test]
    fn fleet_sim_manual_neutral_matches_replica_sim() {
        let s = scenario(PolicyKind::Combined, 60,
                         Arrival::Poisson { rate: 20.0 });
        let plain = run_replica_sim(&s, 1, &RoutePolicy::LeastLoaded)
            .unwrap();
        let fs = FleetScenario {
            base: s,
            initial: vec![ReplicaProfile::baseline()],
            pool: Vec::new(),
            route: RoutePolicy::LeastLoaded,
            policy: FleetPolicyKind::Manual,
            // Zero mix = the no-op class assignment, matching the
            // class-blind replica path.
            mix: [0.0; PriorityClass::COUNT],
        };
        let fleet = run_fleet_sim(&fs).unwrap();
        assert_eq!(fleet.set.to_json().to_string(),
                   plain.to_json().to_string(),
                   "neutral manual fleet must be bit-identical to \
                    run_replica_sim");
        assert_eq!(fleet.controller, "manual");
        assert_eq!(fleet.profiles, vec!["baseline".to_string()]);
        assert_eq!((fleet.n_spawned, fleet.n_retired), (0, 0));
        assert!(fleet.directives.is_empty());
        // Baseline costs 1.0/s held for the whole makespan.
        assert!((fleet.cost_units - fleet.set.aggregate.makespan).abs()
                    < 1e-9,
                "cost {} vs makespan {}", fleet.cost_units,
                fleet.set.aggregate.makespan);
    }

    /// The autoscaler's full cycle in virtual time: a hard burst on one
    /// baseline replica trips the backlog band → spawn(s); the sparse
    /// tail drops under the retire band → retire(s); nothing accepted
    /// is ever lost (retired replicas drain), and the run is
    /// bit-identical across invocations.
    #[test]
    fn fleet_sim_spawns_on_burst_and_retires_in_tail_without_loss() {
        let mut s = scenario(PolicyKind::MemoryAware, 0,
                             Arrival::AllAtOnce);
        s.workload.prompt = LengthDist::Fixed(64);
        s.workload.output = LengthDist::Fixed(128);
        // 3 s @ 60/s (≈ 3× one replica's rate), then 30 s @ 1/s.
        let mut requests: Vec<Request> = (0..180)
            .map(|i| Request::new(i, 64, 128, i as f64 / 60.0))
            .collect();
        for k in 0..30u64 {
            requests.push(Request::new(180 + k, 64, 128, 3.0 + k as f64));
        }
        assign_classes(&mut requests, [0.5, 0.25, 0.25]);
        let total = requests.len();
        let fs = FleetScenario {
            base: s,
            initial: vec![ReplicaProfile::baseline()],
            pool: vec![profile_by_name("economy").unwrap()],
            route: RoutePolicy::LeastLoaded,
            policy: FleetPolicyKind::Autoscale(FleetConfig {
                spawn_backlog: 30.0,
                retire_backlog: 2.0,
                spawn_kv_pressure: 0.95,
                ttft_targets: [None; PriorityClass::COUNT],
                spawn_sla_frac: 0.9,
                retire_sla_frac: 0.5,
                dwell_decisions: 2,
                decide_interval: 0.5,
                cooldown: 2.0,
                min_replicas: 1,
                max_replicas: 3,
            }),
            mix: [0.5, 0.25, 0.25],
        };
        let m = run_fleet_sim_with_requests(&fs, requests.clone())
            .unwrap();
        assert!(m.n_spawned >= 1, "burst must trip a spawn: {:?}",
                m.directives);
        assert!(m.n_retired >= 1, "tail must trip a retire: {:?}",
                m.directives);
        assert_eq!(m.profiles.len(), m.set.n_replicas,
                   "one profile per replica row");
        assert_eq!(m.profiles[0], "baseline");
        assert!(m.profiles[1..].iter().all(|p| p == "economy"),
                "autoscaler spawns the cheapest profile: {:?}",
                m.profiles);
        // Zero-loss: every accepted request finishes even though
        // replicas were retired mid-run.
        assert_eq!(m.set.aggregate.n_finished, total);
        assert_eq!(m.set.aggregate.shed, 0);
        assert!(m.cost_units > 0.0);
        let again = run_fleet_sim_with_requests(&fs, requests).unwrap();
        assert_eq!(m.to_json().to_string(), again.to_json().to_string(),
                   "fleet sim must be deterministic");
    }

    /// Capability routing on a heterogeneous pair: interactive work
    /// lands on the fastest decoder (turbo), long-prompt work on the
    /// biggest KV pool (big-kv).
    #[test]
    fn fleet_sim_capability_routes_by_profile() {
        let mut s = scenario(PolicyKind::MemoryAware, 0,
                             Arrival::AllAtOnce);
        s.workload.prompt = LengthDist::Fixed(64);
        s.workload.output = LengthDist::Fixed(128);
        // 20 short interactive + 20 long batch, interleaved arrivals.
        let mut requests: Vec<Request> = Vec::new();
        for i in 0..20u64 {
            let mut a = Request::new(2 * i, 64, 128, i as f64 * 0.1);
            a.class = PriorityClass::Interactive;
            requests.push(a);
            let mut b =
                Request::new(2 * i + 1, 1024, 128, i as f64 * 0.1 + 0.05);
            b.class = PriorityClass::Batch;
            requests.push(b);
        }
        let fs = FleetScenario {
            base: s,
            initial: vec![profile_by_name("turbo").unwrap(),
                          profile_by_name("big-kv").unwrap()],
            pool: Vec::new(),
            route: RoutePolicy::Capability { long_prompt: 512 },
            policy: FleetPolicyKind::Manual,
            mix: [0.0; PriorityClass::COUNT],
        };
        let m = run_fleet_sim_with_requests(&fs, requests).unwrap();
        assert_eq!(m.set.aggregate.n_finished, 40);
        let turbo = &m.set.per_replica[0];
        let bigkv = &m.set.per_replica[1];
        assert_eq!(turbo.per_class[0].n_requests, 20,
                   "all interactive on the fast decoder");
        assert_eq!(bigkv.per_class[2].n_requests, 20,
                   "all long prompts on the big KV pool");
        assert_eq!(turbo.per_class[2].n_requests, 0);
        assert_eq!(bigkv.per_class[0].n_requests, 0);
    }

    /// The ISSUE acceptance regression: under a bursty mixed-class
    /// workload, the heterogeneous autoscaled fleet must meet the
    /// interactive TTFT target at ≥ 20% lower cost than the cheapest
    /// static homogeneous fleet that also meets it, and the mid-run
    /// scale-down must lose nothing. Arrivals are constructed
    /// arithmetically (no RNG) so the shape is exact: two
    /// [5 s @ 80/s + 5 s @ 2/s] cycles, then a 100 s tail @ 2/s.
    #[test]
    fn fleet_autoscaler_beats_static_fleets_on_cost_at_sla() {
        let mut s = scenario(PolicyKind::MemoryAware, 0,
                             Arrival::AllAtOnce);
        s.workload.prompt = LengthDist::Fixed(64);
        s.workload.output = LengthDist::Fixed(128);
        let mut requests: Vec<Request> = Vec::new();
        let mut id = 0u64;
        let mut push = |reqs: &mut Vec<Request>, t: f64| {
            reqs.push(Request::new(id, 64, 128, t));
            id += 1;
        };
        for cycle in 0..2 {
            let t0 = cycle as f64 * 10.0;
            for i in 0..400 {
                push(&mut requests, t0 + i as f64 / 80.0);
            }
            for j in 0..10 {
                push(&mut requests, t0 + 5.0 + j as f64 * 0.5);
            }
        }
        for k in 0..200 {
            push(&mut requests, 20.0 + k as f64 * 0.5);
        }
        let mix = [0.5, 0.25, 0.25];
        assign_classes(&mut requests, mix);
        let total = requests.len();
        let target = 0.75; // interactive TTFT p95, seconds

        let run = |initial: Vec<ReplicaProfile>,
                   pool: Vec<ReplicaProfile>,
                   policy: FleetPolicyKind| {
            let fs = FleetScenario {
                base: s.clone(),
                initial,
                pool,
                route: RoutePolicy::LeastLoaded,
                policy,
                mix,
            };
            run_fleet_sim_with_requests(&fs, requests.clone()).unwrap()
        };
        let meets = |m: &FleetMetrics| {
            m.set.aggregate.per_class[0].ttft_p95 <= target
                && m.set.aggregate.n_finished == total
                && m.set.aggregate.shed == 0
        };

        // Static homogeneous references at N = 1..3.
        let statics: Vec<FleetMetrics> = (1..=3)
            .map(|n| {
                run(vec![ReplicaProfile::baseline(); n], Vec::new(),
                    FleetPolicyKind::Manual)
            })
            .collect();
        // Burst interactive demand (≈ 40/s) alone exceeds one
        // baseline replica, so N=1 must violate the target.
        assert!(!meets(&statics[0]),
                "N=1 must violate: ttft_p95={}",
                statics[0].set.aggregate.per_class[0].ttft_p95);
        let best_static = statics
            .iter()
            .filter(|m| meets(m))
            .map(|m| m.cost_units)
            .fold(f64::INFINITY, f64::min);
        assert!(best_static.is_finite(),
                "some static size must meet the target");

        // The autoscaled fleet starts provisioned for the burst and
        // sheds capacity in the tail. Spawning is disabled (the burst
        // head is covered); the test exercises the scale-down half.
        let auto = run(
            vec![ReplicaProfile::baseline(),
                 profile_by_name("economy").unwrap(),
                 profile_by_name("economy").unwrap()],
            vec![profile_by_name("economy").unwrap()],
            FleetPolicyKind::Autoscale(FleetConfig {
                spawn_backlog: 1e6,
                retire_backlog: 3.0,
                spawn_kv_pressure: 1.0,
                ttft_targets: [None; PriorityClass::COUNT],
                spawn_sla_frac: 0.9,
                retire_sla_frac: 0.5,
                // Dwell × interval outlasts the 5 s low phases inside
                // the head, so retires only fire in the long tail.
                dwell_decisions: 8,
                decide_interval: 1.0,
                cooldown: 5.0,
                min_replicas: 1,
                max_replicas: 3,
            }),
        );
        assert!(meets(&auto),
                "autoscaled fleet must meet the target: ttft_p95={} \
                 finished={} shed={}",
                auto.set.aggregate.per_class[0].ttft_p95,
                auto.set.aggregate.n_finished, auto.set.aggregate.shed);
        assert!(auto.n_retired >= 1,
                "the tail must trigger scale-down: {:?}", auto.directives);
        assert_eq!(auto.set.aggregate.n_finished, total,
                   "zero-loss scale-down");
        assert_eq!(auto.set.aggregate.shed, 0);
        assert!(auto.cost_units <= 0.8 * best_static,
                "autoscaled cost {} must be ≥ 20% under best static {}",
                auto.cost_units, best_static);
    }

    #[test]
    fn switch_sweep_is_deterministic_and_complete() {
        let mut s = scenario(PolicyKind::StaticFixed { batch: 2 }, 60,
                             Arrival::Poisson { rate: 10.0 });
        s.sched.d_sla = Some(0.05);
        let ats = [1.0, 3.0];
        let spikes = [0usize, 30];
        let rows = switch_sweep(&s, PolicyKind::Combined, &ats, 2.0,
                                &spikes)
            .unwrap();
        assert_eq!(rows.len(), ats.len() * spikes.len());
        for row in &rows {
            let total = 60 + row.spike_requests;
            assert_eq!(row.baseline.n_requests, total,
                       "baseline finished everything");
            assert_eq!(row.switched.n_requests, total,
                       "switched finished everything");
            assert_eq!(row.baseline.reconfigs, 0);
            assert_eq!(row.switched.reconfigs, 1);
        }
        // The spike actually loads the system: the spiked baseline runs
        // longer than the unspiked one.
        assert!(rows[2].baseline.makespan > rows[0].baseline.makespan);
        // Regression property: fixed seeds → bit-identical tables.
        let again = switch_sweep(&s, PolicyKind::Combined, &ats, 2.0,
                                 &spikes)
            .unwrap();
        for (a, b) in rows.iter().zip(&again) {
            assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        }
    }

    #[test]
    fn assign_classes_is_deterministic_and_interleaved() {
        let mut a: Vec<Request> =
            (0..600).map(|i| Request::new(i, 32, 8, 0.0)).collect();
        let mut b = a.clone();
        assign_classes(&mut a, [0.3, 0.2, 0.5]);
        assign_classes(&mut b, [0.3, 0.2, 0.5]);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.class, y.class, "same index → same class");
        }
        let count = |c: PriorityClass| {
            a.iter().filter(|r| r.class == c).count()
        };
        let (i, s, bt) = (count(PriorityClass::Interactive),
                          count(PriorityClass::Standard),
                          count(PriorityClass::Batch));
        assert_eq!(i + s + bt, 600);
        // Roughly the requested mix (hash-uniform, wide tolerance).
        assert!((120..=240).contains(&i), "interactive {i}");
        assert!((60..=180).contains(&s), "standard {s}");
        assert!((210..=390).contains(&bt), "batch {bt}");
        // Interleaved: the first 50 requests already carry ≥ 2 classes.
        let head: std::collections::HashSet<_> =
            a[..50].iter().map(|r| r.class.rank()).collect();
        assert!(head.len() >= 2, "classes must interleave, got {head:?}");
        // Zero mix is a no-op.
        let mut c = b.clone();
        assign_classes(&mut c, [0.0, 0.0, 0.0]);
        assert!(c.iter().all(|r| r.class == PriorityClass::Standard));
    }

    /// The `dynabatch sla` acceptance regression: under mixed Poisson
    /// load on the Fig. 3 model, tightening ONLY the interactive target
    /// to 50 ms (batch unconstrained) must bring the
    /// interactive-attributed decode p95 to the target (the baseline
    /// violates it) while aggregate throughput stays within the paper's
    /// capacity trade-off envelope — and the sweep must be bit-identical
    /// across runs.
    #[test]
    fn per_class_sla_sweep_meets_interactive_target_within_envelope() {
        let model = llama3_70b();
        let hardware = node_for(&model);
        let scenario = SimScenario {
            model,
            hardware,
            sched: SchedulerConfig {
                policy: PolicyKind::MemoryAware,
                // A short latency window keeps the feedback lag (and so
                // the admission-ramp overshoot past the target) small —
                // the operator knob the OPERATIONS runbook documents
                // for tight interactive targets.
                latency_window: 16,
                ..SchedulerConfig::default()
            },
            workload: Workload {
                name: "sla-mixed".into(),
                arrival: Arrival::Poisson { rate: 20.0 },
                prompt: LengthDist::Fixed(256),
                output: LengthDist::Fixed(128),
                n_requests: 300,
                seed: 11,
                prefix: None,
                length_mix: None,
            },
            eta_tokens_override: None,
            swap_tokens: 0,
        };
        let d = 0.050;
        let targets = [[Some(d), None, None]];
        let mix = [0.3, 0.2, 0.5];
        let rows = sla_sweep(&scenario, &targets, mix).unwrap();
        assert_eq!(rows.len(), 2);
        let base = &rows[0].metrics;
        let tight = &rows[1].metrics;
        assert_eq!(rows[1].label, "per-class-sla(interactive=50)");
        assert_eq!(base.n_requests, 300);
        assert_eq!(tight.n_requests, 300, "no request lost to the cap");

        let base_ic = &base.per_class[0];
        let tight_ic = &tight.per_class[0];
        assert!(base_ic.n_requests > 0 && tight.per_class[2].n_requests > 0,
                "mixed load carries both ends of the class range");
        // The baseline saturates past the 50 ms point…
        assert!(base_ic.tbt_p95 > d + scenario.sched.eps_d,
                "baseline must violate for the target to bind: p95={}",
                base_ic.tbt_p95);
        // …the per-class controller pulls interactive back to the
        // target envelope. The offered rate is above the 50 ms SLA
        // capacity, so Alg. 2's line-15 clamp (`b ≥ N^d`) legitimately
        // pins slightly past the target by the admission-ramp overshoot
        // (window lag × arrival rate) — the 25% envelope covers that
        // pin; the paper's capacity definition makes exact attainment
        // above capacity impossible by construction.
        assert!(tight_ic.tbt_p95 <= d * 1.25,
                "interactive p95 {} misses the 50ms target envelope",
                tight_ic.tbt_p95);
        assert!(tight_ic.tbt_p95 < 0.9 * base_ic.tbt_p95,
                "tightening must visibly move interactive latency: {} vs {}",
                tight_ic.tbt_p95, base_ic.tbt_p95);
        assert_eq!(tight_ic.sla_target, Some(d));
        assert!(tight_ic.sla_violation_rate.unwrap()
                    < 0.8,
                "violation accounting present and bounded");
        assert_eq!(tight.per_class[2].sla_target, None,
                   "batch stays unconstrained");
        // Throughput envelope: the paper's Fig. 3 capacity trade-off
        // (≈ 0.7× at a 50 ms SLA on this model), with slack.
        assert!(tight.throughput >= 0.55 * base.throughput,
                "throughput collapsed beyond the capacity trade-off: \
                 {} vs {}",
                tight.throughput, base.throughput);
        // Batch traffic keeps flowing under the interactive cap.
        assert!(tight.per_class[2].output_tokens > 0);

        // Fixed seeds → bit-identical sweep tables.
        let again = sla_sweep(&scenario, &targets, mix).unwrap();
        for (a, b) in rows.iter().zip(&again) {
            assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        }
    }

    #[test]
    fn replica_sim_attaches_per_class_metrics() {
        let mut s = scenario(PolicyKind::MemoryAware, 80,
                             Arrival::AllAtOnce);
        s.sched.policy =
            PolicyKind::PerClassSla([Some(0.5), None, None]);
        let set =
            run_replica_sim(&s, 2, &RoutePolicy::LeastLoaded).unwrap();
        assert_eq!(set.aggregate.per_class.len(), 3);
        for m in &set.per_replica {
            assert_eq!(m.per_class.len(), 3);
        }
        // All workload-generated requests are Standard; the aggregate
        // per-class rows must reflect that.
        assert_eq!(set.aggregate.per_class[1].n_requests, 80);
        assert_eq!(set.aggregate.per_class[0].n_requests, 0);
        assert!(set.aggregate.per_class[1].tbt_p95 > 0.0);
        assert_eq!(set.aggregate.per_class[0].sla_target, Some(0.5));
    }

    /// A memory-bound multi-tenant regime: tiny KV pool, a 512-token
    /// tenant prefix dwarfing the 32-token private suffix, greedy
    /// batching so admission is gated by KV room alone. Sharing admits
    /// each tenant prefix once instead of per request.
    fn prefix_scenario() -> SimScenario {
        use crate::workload::SharedPrefixSpec;
        let model = pangu_7b();
        let hardware = node_for(&model);
        SimScenario {
            model,
            hardware,
            sched: SchedulerConfig {
                policy: PolicyKind::StaticGreedy { max: 256 },
                ..SchedulerConfig::default()
            },
            workload: Workload {
                name: "prefix-mt".into(),
                arrival: Arrival::Poisson { rate: 1.0 },
                prompt: LengthDist::Fixed(32), // private-suffix length
                output: LengthDist::Fixed(64),
                n_requests: 60,
                seed: 91,
                prefix: Some(SharedPrefixSpec {
                    n_prefixes: 4,
                    prefix_tokens: 512,
                    zipf_s: 1.1,
                }),
                length_mix: None,
            },
            eta_tokens_override: Some(6_000),
            swap_tokens: 0,
        }
    }

    #[test]
    fn prefix_sharing_multiplies_capacity_deterministically() {
        // The PR's headline regression: on Zipf shared-prefix traffic,
        // prefix sharing must sustain ≥ 1.5× the users of the
        // no-sharing baseline at the same p95 SLA — and the whole
        // comparison must be bit-identical per seed.
        let s = prefix_scenario();
        let r = prefix_capacity(&s, 0.5, 0.01, 95.0, 60, 0.25).unwrap();
        assert!(r.baseline.capacity_qps > 0.0,
                "baseline sustains something");
        assert!(
            r.ratio >= 1.5,
            "sharing must carry ≥1.5× the users: baseline {:.2} qps, \
             shared {:.2} qps (ratio {:.2})",
            r.baseline.capacity_qps,
            r.shared.capacity_qps,
            r.ratio
        );
        assert!(
            r.shared.at_capacity.prefix_hit_rate.unwrap() > 0.5,
            "the hot tenant prefixes must actually hit"
        );
        assert!(r.baseline.at_capacity.prefix_hit_rate.is_none(),
                "the baseline never consulted the tree");
        let again = prefix_capacity(&s, 0.5, 0.01, 95.0, 60, 0.25)
            .unwrap();
        assert_eq!(r.to_json().to_string(), again.to_json().to_string(),
                   "same seed → bit-identical regression");
    }

    #[test]
    fn prefix_capacity_requires_a_multi_tenant_workload() {
        let mut s = prefix_scenario();
        s.workload.prefix = None;
        assert!(prefix_capacity(&s, 0.5, 0.01, 95.0, 40, 0.25).is_err());
    }

    #[test]
    fn shared_run_reports_hit_rate_and_beats_baseline() {
        // One fixed-rate run each way: sharing at minimum matches the
        // baseline's completion and reports its hit rate; the baseline
        // reports None (no tree consulted).
        let mut s = prefix_scenario();
        s.workload.arrival = Arrival::AllAtOnce;
        s.workload.n_requests = 120;
        let base = run_sim(&s).unwrap();
        assert_eq!(base.prefix_hit_rate, None);
        s.sched.prefix_cache = true;
        let shared = run_sim(&s).unwrap();
        assert_eq!(shared.n_finished, 120);
        assert!(shared.prefix_hit_rate.unwrap() > 0.5,
                "hit rate {:?}", shared.prefix_hit_rate);
        assert!(
            shared.makespan < base.makespan,
            "sharing must finish the memory-bound burst sooner: \
             {:.2}s vs {:.2}s",
            shared.makespan,
            base.makespan
        );
    }

    #[test]
    fn capacity_search_brackets_sla() {
        let mut s = scenario(PolicyKind::Combined, 0,
                             Arrival::Poisson { rate: 1.0 });
        s.sched.d_sla = Some(0.05);
        s.workload.prompt = LengthDist::Fixed(64);
        s.workload.output = LengthDist::Fixed(32);
        let cap = capacity_search(&s, 0.05, 0.002, 95.0, 200, 0.25).unwrap();
        assert!(cap.capacity_qps > 0.0);
        // Capacity is finite: the stability criterion must bite well below
        // the bracket ceiling even though the TBT gate never trips.
        assert!(cap.capacity_qps < 500.0, "cap={}", cap.capacity_qps);
        assert!(cap.at_capacity.meets_sla(0.05, 0.002, 95.0));
        // Sustained 2× overload must fail the stability criterion the
        // search uses (TTFT / makespan), i.e. the bracket is real.
        let rate = cap.capacity_qps * 2.0 + 1.0;
        let n = 200usize.max((rate * 20.0) as usize);
        let mut above = s.clone();
        above.workload =
            s.workload.with_arrival(Arrival::Poisson { rate });
        above.workload.n_requests = n;
        let m = run_sim(&above).unwrap();
        let span = n as f64 / rate;
        let unstable = m.ttft_p95 > 2.0
            || m.makespan > span * 1.15 + 2.0
            || !m.meets_sla(0.05, 0.002, 95.0);
        assert!(unstable, "2x overload should be unstable (ttft_p95={}, \
                makespan={span_m}, span={span})", m.ttft_p95,
                span_m = m.makespan);
    }

    /// The bucketing regression's traffic: 80% short chat turns (16–32
    /// tokens), 20% long documents (~1k), everything at t=0 so flat
    /// admission pads every short prompt up to the longest in the step.
    /// Small outputs keep the run prefill-dominated — the regime where
    /// padding waste decides throughput.
    fn bucket_scenario() -> SimScenario {
        let model = pangu_7b();
        let hardware = node_for(&model);
        SimScenario {
            model,
            hardware,
            sched: SchedulerConfig {
                policy: PolicyKind::StaticGreedy { max: 256 },
                buckets: 4,
                bucket_base: 64,
                ..SchedulerConfig::default()
            },
            workload: Workload {
                name: "bucket-mini".into(),
                arrival: Arrival::AllAtOnce,
                prompt: LengthDist::Fixed(128), // nominal; mix overrides
                output: LengthDist::Fixed(8),
                n_requests: 64,
                seed: 17,
                prefix: None,
                length_mix: Some(LengthMix::bimodal(16, 32, 1024.0, 0.2,
                                                    2048)),
            },
            eta_tokens_override: Some(200_000),
            swap_tokens: 0,
        }
    }

    #[test]
    fn bucketed_beats_flat_on_long_tail_traffic() {
        // The PR's headline regression: under rectangular-kernel padding
        // accounting, length-bucketed admission must buy >= 1.15x
        // throughput on bimodal traffic while leaving the decode path
        // untouched.
        let r = bucket_compare(&bucket_scenario()).unwrap();
        assert_eq!(r.flat.n_finished, 64);
        assert_eq!(r.bucketed.n_finished, 64);
        assert!(r.ratio >= 1.15,
                "bucketing must kill enough padding: ratio {:.3} \
                 (flat {:.0} tok/s, bucketed {:.0} tok/s)",
                r.ratio, r.flat.throughput, r.bucketed.throughput);
        // Decode steps are identical in both arms (same admission, same
        // batch, padding charges compute on prefill groups only), so the
        // decode p95 matches *exactly* — bucketing must not trade TBT
        // for throughput.
        assert_eq!(r.flat.tbt_p95.to_bits(), r.bucketed.tbt_p95.to_bits(),
                   "decode p95 drifted: flat {} vs bucketed {}",
                   r.flat.tbt_p95, r.bucketed.tbt_p95);
        // Waste accounting points the same way the throughput does.
        let wf = r.flat.padding_waste.unwrap();
        let wb = r.bucketed.padding_waste.unwrap();
        assert!(wb < wf, "bucketed waste {wb} >= flat waste {wf}");
        assert!(wf > 0.5, "flat arm must be padding-dominated: {wf}");
    }

    #[test]
    fn bucket_compare_is_bit_identical_per_seed() {
        let a = bucket_compare(&bucket_scenario()).unwrap();
        let b = bucket_compare(&bucket_scenario()).unwrap();
        assert_eq!(a.ratio.to_bits(), b.ratio.to_bits());
        assert_eq!(a.flat.throughput.to_bits(),
                   b.flat.throughput.to_bits());
        assert_eq!(a.bucketed.throughput.to_bits(),
                   b.bucketed.throughput.to_bits());
        assert_eq!(a.bucketed.padded_prefill_tokens,
                   b.bucketed.padded_prefill_tokens);
        // And the result shape survives its JSON projection.
        let j = a.to_json();
        let s = j.to_string_pretty();
        assert!(s.contains("\"ratio\""));
        assert!(s.contains("\"bucketed_padding_waste\""));
    }

    #[test]
    fn bucket_compare_requires_buckets() {
        let mut s = bucket_scenario();
        s.sched.buckets = 0;
        assert!(bucket_compare(&s).is_err());
    }

    #[test]
    fn padding_stats_absent_without_accounting() {
        // The default path never charges padding, so the metrics report
        // None rather than a misleading zero.
        let s = scenario(PolicyKind::MemoryAware, 40, Arrival::AllAtOnce);
        let m = run_sim(&s).unwrap();
        assert_eq!(m.padded_prefill_tokens, None);
        assert_eq!(m.padding_waste, None);
    }
}
