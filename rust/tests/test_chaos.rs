//! Chaos-layer coverage from the public API: random fault plans must
//! never lose an accepted request — every id ends in exactly one
//! terminal event — and any fixed plan must replay bit-identically
//! under the same seed.

use dynabatch::config::presets::*;
use dynabatch::config::{PolicyKind, SchedulerConfig};
use dynabatch::driver::{run_chaos_sim, Fault, FaultPlan, SimScenario};
use dynabatch::service::{HealthPolicy, RoutePolicy};
use dynabatch::util::prop::{check, Gen};
use dynabatch::workload::{Arrival, LengthDist, Workload};

fn scenario(n_requests: usize, rate: f64, seed: u64) -> SimScenario {
    let model = llama_65b();
    let hardware = node_for(&model);
    SimScenario {
        model,
        hardware,
        sched: SchedulerConfig {
            policy: PolicyKind::Combined,
            ..SchedulerConfig::default()
        },
        workload: Workload {
            name: "chaos-prop".into(),
            arrival: Arrival::Poisson { rate },
            prompt: LengthDist::around(68.4, 256),
            output: LengthDist::around(80.0, 256),
            n_requests,
            seed,
            prefix: None,
            length_mix: None,
        },
        eta_tokens_override: None,
        swap_tokens: 0,
    }
}

/// A random-but-valid fault plan. Replica 0 is never crashed so the
/// zero-loss property always has a landing spot for re-routed work
/// (crashing the whole set legitimately loses in-flight prompts).
fn random_plan(g: &mut Gen, n_replicas: usize) -> FaultPlan {
    let mut faults = Vec::new();
    for _ in 0..g.usize(0..=3) {
        let at = g.f64(0.2, 6.0);
        match g.usize(0..=2) {
            0 if n_replicas > 1 => faults.push(Fault::Crash {
                replica: g.usize(1..=n_replicas - 1),
                at,
            }),
            0 => {}
            1 => faults.push(Fault::Slow {
                replica: g.usize(0..=n_replicas - 1),
                at,
                factor: g.f64(2.0, 8.0),
                duration: if g.bool_with(0.2) {
                    f64::INFINITY // never heals
                } else {
                    g.f64(0.5, 3.0)
                },
            }),
            _ => faults.push(Fault::Partition {
                replicas: vec![g.usize(0..=n_replicas - 1)],
                at,
                duration: g.f64(0.5, 2.0),
            }),
        }
    }
    FaultPlan {
        faults,
        health: HealthPolicy {
            suspect_factor: g.f64(1.5, 4.0),
            ..HealthPolicy::default()
        },
        hedging: g.bool(),
        ..FaultPlan::default()
    }
}

/// The tentpole invariant: whatever the interleaving of crashes,
/// stragglers, partitions, detector transitions and hedges, an
/// accepted request is never silently dropped — `lost` counts exactly
/// the accepted ids with no terminal record anywhere in the set.
#[test]
fn prop_random_fault_plans_lose_nothing() {
    check("chaos zero-loss under random fault plans", 25, |g| {
        let n_replicas = g.usize(2..=3);
        let s = scenario(
            g.usize(20..=45),
            g.f64(8.0, 25.0),
            g.u64(1..=1_000),
        );
        let plan = random_plan(g, n_replicas);
        let has_crash = plan
            .faults
            .iter()
            .any(|f| matches!(f, Fault::Crash { .. }));
        let m = run_chaos_sim(
            &s,
            n_replicas,
            &RoutePolicy::LeastLoaded,
            &plan,
        )
        .unwrap();
        // `failed` (typed terminal errors) can only come from a crash
        // cutting off a mid-decode request; nothing else may fail.
        m.lost == 0 && (has_crash || m.failed == 0)
    });
}

/// A mixed plan — straggler, crash and partition in one run — replays
/// bit-identically under the same seed, the property that makes chaos
/// tables usable as regression anchors.
#[test]
fn chaos_mixed_plan_replays_bit_identically() {
    let s = scenario(60, 12.0, 7);
    let plan = FaultPlan {
        faults: vec![
            Fault::Slow { replica: 1, at: 0.5, factor: 3.0,
                          duration: 2.0 },
            Fault::Crash { replica: 2, at: 1.5 },
            Fault::Partition { replicas: vec![0], at: 3.0,
                               duration: 1.0 },
        ],
        mix: [0.4, 0.3, 0.3],
        ..FaultPlan::default()
    };
    let a = run_chaos_sim(&s, 3, &RoutePolicy::LeastLoaded, &plan)
        .unwrap();
    let b = run_chaos_sim(&s, 3, &RoutePolicy::LeastLoaded, &plan)
        .unwrap();
    assert_eq!(a.to_json().to_string(), b.to_json().to_string(),
               "same seed + same plan → bit-identical chaos metrics");
    assert_eq!(a.lost, 0, "mixed plan must not lose requests");
    assert_eq!(a.crashes, 1);
    assert_eq!(a.partitions, 1);
    assert_eq!(a.recovered, 1, "the partition must heal");
}

#[test]
fn chaos_plan_validation_rejects_nonsense() {
    let s = scenario(10, 10.0, 1);
    let bad = [
        FaultPlan {
            faults: vec![Fault::Crash { replica: 5, at: 1.0 }],
            ..FaultPlan::default()
        },
        FaultPlan {
            faults: vec![Fault::Slow { replica: 0, at: -1.0,
                                       factor: 2.0, duration: 1.0 }],
            ..FaultPlan::default()
        },
        FaultPlan {
            faults: vec![Fault::Slow { replica: 0, at: 1.0,
                                       factor: 0.0, duration: 1.0 }],
            ..FaultPlan::default()
        },
        FaultPlan {
            faults: vec![Fault::Partition { replicas: vec![], at: 1.0,
                                            duration: 1.0 }],
            ..FaultPlan::default()
        },
        FaultPlan {
            faults: vec![Fault::Partition { replicas: vec![0], at: 1.0,
                                            duration: f64::INFINITY }],
            ..FaultPlan::default()
        },
    ];
    for plan in bad {
        assert!(
            run_chaos_sim(&s, 2, &RoutePolicy::LeastLoaded, &plan)
                .is_err(),
            "plan must be rejected: {:?}",
            plan.faults
        );
    }
}
