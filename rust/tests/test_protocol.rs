//! Protocol battery for the event-loop serving edge: golden byte-for-byte
//! frame pins (the serializers are BTreeMap-backed, so compact output is
//! byte-stable), fixed-seed property/fuzz tests of the zero-copy line
//! framer (arbitrary chunking / merging / truncation / garbage must never
//! panic and never misframe), parser round-trips for the v1/v2 `generate`
//! forms, and live-wire pins of every state-independent response frame.

use dynabatch::config::presets::{cpu_host, tiny_real};
use dynabatch::config::{PolicyKind, SchedulerConfig};
use dynabatch::engine::sim::SimEngine;
use dynabatch::engine::Engine;
use dynabatch::request::PriorityClass;
use dynabatch::scheduler::Scheduler;
use dynabatch::server::protocol::{
    conn_error, event_to_json, overload_json, parse_generate,
    parse_replica, sampling_from_json, FrameBuf, WriteBuf,
};
use dynabatch::server::{serve, EdgeConfig, Server};
use dynabatch::service::GenEvent;
use dynabatch::tokenizer;
use dynabatch::util::json::Json;
use dynabatch::util::rng::Rng;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

fn compact(j: &Json) -> String {
    let mut s = String::new();
    j.write_compact(&mut s);
    s
}

// ------------------------------------------------- golden serializer pins

#[test]
fn golden_event_frames_byte_for_byte() {
    let cases: Vec<(GenEvent, &str)> = vec![
        (
            GenEvent::Accepted { id: 7, class: PriorityClass::Interactive },
            r#"{"class":"interactive","id":7,"type":"accepted"}"#,
        ),
        (
            GenEvent::Token { id: 7, token: 104, text: "h".into() },
            r#"{"id":7,"text":"h","token":104,"type":"token"}"#,
        ),
        (
            // Exact-in-binary latencies so ms scaling stays integral.
            GenEvent::Done {
                id: 7,
                text: "hi".into(),
                n_tokens: 2,
                ttft: 0.5,
                e2e: 2.0,
            },
            r#"{"e2e_ms":2000,"id":7,"n_tokens":2,"text":"hi","ttft_ms":500,"type":"done"}"#,
        ),
        (
            GenEvent::Error { id: 3, message: "boom".into() },
            r#"{"error":"boom","id":3,"type":"error"}"#,
        ),
        (
            GenEvent::Cancelled { id: 9 },
            r#"{"id":9,"type":"cancelled"}"#,
        ),
    ];
    for (ev, want) in &cases {
        assert_eq!(&compact(&event_to_json(ev)), want);
    }
}

#[test]
fn golden_connection_frames_byte_for_byte() {
    assert_eq!(
        compact(&conn_error("bad json: oops".into())),
        r#"{"error":"bad json: oops","type":"error"}"#
    );
    assert_eq!(
        compact(&overload_json(64, 50.0, "edge")),
        concat!(
            r#"{"error":"server overloaded (edge limit 64 reached); "#,
            r#"retry in 50 ms","limit":64,"retry_ms":50,"shed":"edge"}"#
        )
    );
    assert_eq!(
        compact(&overload_json(4096, 50.0, "accept")),
        concat!(
            r#"{"error":"server overloaded (accept limit 4096 reached); "#,
            r#"retry in 50 ms","limit":4096,"retry_ms":50,"shed":"accept"}"#
        )
    );
}

// ------------------------------------------------------- parser round-trip

#[test]
fn parse_generate_v1_and_v2_forms() {
    // v1: text prompt through the byte tokenizer, defaults everywhere.
    let v1 = Json::parse(r#"{"op":"generate","prompt":"hi"}"#).unwrap();
    let r = parse_generate(&v1).unwrap();
    assert_eq!(r.prompt_tokens, tokenizer::encode("hi"));
    assert_eq!(r.max_new_tokens, 16);
    assert_eq!(r.class, PriorityClass::Standard);
    assert_eq!(r.deadline, None);

    // v2: raw token ids + class + deadline + sampling.
    let v2 = Json::parse(concat!(
        r#"{"op":"generate","prompt_tokens":[256,104,105],"#,
        r#""max_new_tokens":32,"class":"interactive","#,
        r#""deadline_ms":1500,"#,
        r#""sampling":{"temperature":0.7,"top_k":40,"top_p":0.9,"#,
        r#""seed":1}}"#
    ))
    .unwrap();
    let r = parse_generate(&v2).unwrap();
    assert_eq!(r.prompt_tokens, vec![256, 104, 105]);
    assert_eq!(r.max_new_tokens, 32);
    assert_eq!(r.class, PriorityClass::Interactive);
    assert_eq!(r.deadline, Some(1.5));
    assert_eq!(r.sampling.top_k, 40);
    assert_eq!(r.sampling.seed, Some(1));

    // max_new_tokens is clamped to >= 1; fractional prompt ids error.
    let z = Json::parse(
        r#"{"op":"generate","prompt":"x","max_new_tokens":0}"#,
    )
    .unwrap();
    assert_eq!(parse_generate(&z).unwrap().max_new_tokens, 1);
    let bad =
        Json::parse(r#"{"op":"generate","prompt_tokens":[1.5]}"#).unwrap();
    assert!(parse_generate(&bad).is_err());
}

#[test]
fn parse_replica_strict_on_malformed() {
    let none = Json::parse(r#"{"op":"drain"}"#).unwrap();
    assert_eq!(parse_replica(&none).unwrap(), None);
    let some = Json::parse(r#"{"op":"drain","replica":2}"#).unwrap();
    assert_eq!(parse_replica(&some).unwrap(), Some(2));
    for bad in [
        r#"{"op":"drain","replica":"0"}"#,
        r#"{"op":"drain","replica":-1}"#,
        r#"{"op":"drain","replica":1.5}"#,
    ] {
        let msg = Json::parse(bad).unwrap();
        assert!(parse_replica(&msg).is_err(), "{bad} must error");
    }
}

#[test]
fn sampling_defaults_fill_missing_fields() {
    let s = sampling_from_json(&Json::parse("{}").unwrap());
    assert_eq!(s.temperature, 0.0);
    assert_eq!(s.top_k, 0);
    assert_eq!(s.top_p, 1.0);
    assert_eq!(s.seed, None);
}

// --------------------------------------------- framer property/fuzz tests

/// A corpus that exercises the framer's edges: tiny frames, a frame
/// larger than the 4096-byte compaction threshold, `\r\n` endings, and
/// whitespace-only lines.
fn corpus() -> Vec<Vec<u8>> {
    let big = format!(r#"{{"pad":"{}"}}"#, "x".repeat(6000));
    vec![
        br#"{"op":"stats"}"#.to_vec(),
        b"".to_vec(),
        br#"{"op":"generate","prompt":"hi","max_new_tokens":2}"#.to_vec(),
        b"  \t ".to_vec(),
        big.into_bytes(),
        br#"{"op":"cancel","id":7}"#.to_vec(),
    ]
}

fn wire_bytes(frames: &[Vec<u8>], crlf: bool) -> Vec<u8> {
    let mut out = Vec::new();
    for f in frames {
        out.extend_from_slice(f);
        if crlf {
            out.push(b'\r');
        }
        out.push(b'\n');
    }
    out
}

fn one_shot_frames(bytes: &[u8]) -> Vec<Vec<u8>> {
    let mut fb = FrameBuf::new();
    fb.extend(bytes);
    let mut out = Vec::new();
    while let Some(f) = fb.next_frame() {
        out.push(f.to_vec());
    }
    out
}

#[test]
fn chunking_never_changes_framing() {
    for crlf in [false, true] {
        let bytes = wire_bytes(&corpus(), crlf);
        let want = one_shot_frames(&bytes);
        assert_eq!(want.len(), corpus().len());
        // The \r is stripped, the \n consumed, the payload untouched.
        for (w, c) in want.iter().zip(corpus()) {
            assert_eq!(w, &c);
        }
        let mut rng = Rng::new(0xF00D + crlf as u64);
        for _ in 0..60 {
            let mut fb = FrameBuf::new();
            let mut got = Vec::new();
            let mut i = 0;
            while i < bytes.len() {
                let n = rng.range_usize(1, 97).min(bytes.len() - i);
                fb.extend(&bytes[i..i + n]);
                i += n;
                while let Some(f) = fb.next_frame() {
                    got.push(f.to_vec());
                }
            }
            assert_eq!(got, want, "chunked parse diverged");
        }
    }
}

#[test]
fn truncated_tail_is_held_not_yielded() {
    let mut fb = FrameBuf::new();
    fb.extend(br#"{"op":"stats"}"#); // no newline yet
    assert!(fb.next_frame().is_none());
    assert_eq!(fb.buffered(), 14);
    fb.extend(b"\n");
    assert_eq!(fb.next_frame().unwrap(), br#"{"op":"stats"}"#);
    assert!(fb.next_frame().is_none());
    assert_eq!(fb.buffered(), 0);
}

#[test]
fn garbage_streams_never_panic_or_misframe() {
    let mut rng = Rng::new(0xBAD5EED);
    for _ in 0..200 {
        let mut fb = FrameBuf::new();
        let len = rng.range_usize(0, 512);
        let mut bytes = Vec::with_capacity(len);
        for _ in 0..len {
            // Bias toward newlines and high bytes (invalid UTF-8).
            bytes.push(match rng.below(8) {
                0 => b'\n',
                1 => b'\r',
                2 => 0xFF,
                _ => rng.below(256) as u8,
            });
        }
        fb.extend(&bytes);
        while let Some(frame) = fb.next_frame() {
            // Frames must never contain the delimiter...
            assert!(!frame.contains(&b'\n'));
            // ...and downstream decode must fail typed, not panic.
            if let Ok(text) = std::str::from_utf8(frame) {
                let _ = Json::parse(text);
            }
        }
        // Whatever remains is a partial line, bounded by the input.
        assert!(fb.buffered() <= bytes.len());
    }
}

#[test]
fn write_buf_preserves_bytes_under_tiny_writes() {
    /// Accepts one byte per call — the pathological trickle writer.
    struct OneByte(Vec<u8>);
    impl Write for OneByte {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.0.push(buf[0]);
            Ok(1)
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }
    let mut wb = WriteBuf::new();
    let mut scratch = String::new();
    let frames = [
        Json::obj(vec![("type", Json::from("bye"))]),
        conn_error("x".into()),
        overload_json(1, 50.0, "edge"),
    ];
    let mut want = String::new();
    for f in &frames {
        wb.push_line(f, &mut scratch);
        f.write_compact(&mut want);
        want.push('\n');
    }
    let mut sink = OneByte(Vec::new());
    let mut total = 0;
    while wb.pending() > 0 {
        total += wb.flush_into(&mut sink).unwrap();
    }
    assert_eq!(total, want.len());
    assert_eq!(sink.0, want.as_bytes());
}

// ------------------------------------------------------- live-wire pins

fn sim_server() -> Arc<Server> {
    let model = tiny_real();
    let hw = cpu_host();
    let cfg = SchedulerConfig {
        policy: PolicyKind::Combined,
        d_sla: Some(0.05),
        ..SchedulerConfig::default()
    };
    let sched = Scheduler::new(cfg, 100_000, 0, 16.0, 8.0);
    serve(
        move || Ok(Box::new(SimEngine::new(&model, &hw)) as Box<dyn Engine>),
        sched,
        "127.0.0.1:0",
    )
    .unwrap()
}

fn raw_conn(server: &Server) -> (TcpStream, BufReader<TcpStream>) {
    let s = TcpStream::connect(server.local_addr).unwrap();
    let r = BufReader::new(s.try_clone().unwrap());
    (s, r)
}

fn read_line(r: &mut BufReader<TcpStream>) -> String {
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    line.trim_end().to_string()
}

#[test]
fn live_v1_generate_first_frame_pinned_byte_for_byte() {
    let server = sim_server();
    let (mut s, mut r) = raw_conn(&server);
    // First request on a fresh single-replica server: id namespace
    // starts at 1, so the whole accepted frame is state-independent.
    s.write_all(b"{\"op\":\"generate\",\"prompt\":\"hi\",\
                   \"max_new_tokens\":2}\n")
        .unwrap();
    assert_eq!(
        read_line(&mut r),
        r#"{"class":"standard","id":1,"type":"accepted"}"#
    );
    // The stream then carries exactly 2 tokens and one `done`.
    let mut tokens = 0;
    loop {
        let line = read_line(&mut r);
        let j = Json::parse(&line).unwrap();
        match j.get("type").as_str() {
            Some("token") => {
                tokens += 1;
                assert_eq!(j.get("id").as_u64(), Some(1));
            }
            Some("done") => {
                assert_eq!(j.get("n_tokens").as_u64(), Some(2));
                break;
            }
            other => panic!("unexpected frame {other:?}: {line}"),
        }
    }
    assert_eq!(tokens, 2);
    server.shutdown();
}

#[test]
fn live_error_and_bye_frames_pinned_byte_for_byte() {
    let server = sim_server();
    let (mut s, mut r) = raw_conn(&server);
    s.write_all(b"{\"op\":\"nope\"}\n").unwrap();
    assert_eq!(
        read_line(&mut r),
        r#"{"error":"unknown op \"nope\"","type":"error"}"#
    );
    s.write_all(b"not json at all\n").unwrap();
    let line = read_line(&mut r);
    assert!(line.starts_with(r#"{"error":"bad json:"#), "{line}");
    // The connection survived both malformed frames.
    s.write_all(b"{\"op\":\"shutdown\"}\n").unwrap();
    assert_eq!(read_line(&mut r), r#"{"type":"bye"}"#);
    server.shutdown();
}

#[test]
fn live_edge_shed_frame_pinned_byte_for_byte() {
    use dynabatch::service::{ReplicaSet, RoutePolicy, ServiceBuilder};
    // max_inflight 0: every generate is shed at the edge, so the
    // overload frame is fully state-independent.
    let set = ReplicaSet::build(1, RoutePolicy::LeastLoaded, |_| {
        ServiceBuilder::new(tiny_real(), cpu_host())
            .policy(PolicyKind::Combined)
            .d_sla(0.05)
            .eta_tokens(100_000)
    })
    .unwrap();
    let server = dynabatch::server::serve_replicas_with(
        set,
        "127.0.0.1:0",
        EdgeConfig { max_inflight: 0, ..EdgeConfig::default() },
    )
    .unwrap();
    let (mut s, mut r) = raw_conn(&server);
    s.write_all(b"{\"op\":\"generate\",\"prompt\":\"hi\"}\n").unwrap();
    assert_eq!(
        read_line(&mut r),
        concat!(
            r#"{"error":"server overloaded (edge limit 0 reached); "#,
            r#"retry in 50 ms","limit":0,"retry_ms":50,"shed":"edge"}"#
        )
    );
    // The shed is pre-scheduler: the connection stays usable for
    // admin ops, and nothing reached the waiting queue.
    s.write_all(b"{\"op\":\"stats\"}\n").unwrap();
    let stats = Json::parse(&read_line(&mut r)).unwrap();
    assert_eq!(stats.get("type").as_str(), Some("stats"));
    assert_eq!(stats.get("waiting").as_u64(), Some(0));
    assert_eq!(stats.get("running").as_u64(), Some(0));
    assert_eq!(stats.get("edge_sheds").as_u64(), Some(1));
    server.shutdown();
}

#[test]
fn live_v2_ops_round_trip_with_edge_fields() {
    let server = sim_server();
    let (mut s, mut r) = raw_conn(&server);
    // stats: the v2 shape plus the additive edge_* counters.
    s.write_all(b"{\"op\":\"stats\"}\n").unwrap();
    let stats = Json::parse(&read_line(&mut r)).unwrap();
    for key in [
        "running",
        "waiting",
        "kv_used_tokens",
        "controller",
        "n_replicas",
        "route_policy",
        "edge_accepted_conns",
        "edge_open_conns",
        "edge_inflight",
        "edge_sheds",
        "edge_frames",
        "edge_bad_frames",
    ] {
        assert!(!stats.get(key).is_null(), "stats missing {key}");
    }
    assert_eq!(stats.get("edge_open_conns").as_u64(), Some(1));
    // set_policy round-trip.
    s.write_all(b"{\"op\":\"set_policy\",\"policy\":\"alg1\"}\n")
        .unwrap();
    let rep = Json::parse(&read_line(&mut r)).unwrap();
    assert_eq!(rep.get("type").as_str(), Some("policy_set"));
    assert!(rep.get("policy").as_str().is_some());
    // cancel ack for an unknown id still answers (typed, same conn).
    s.write_all(b"{\"op\":\"cancel\",\"id\":424242}\n").unwrap();
    let ack = Json::parse(&read_line(&mut r)).unwrap();
    assert_eq!(ack.get("type").as_str(), Some("cancel_ack"));
    assert_eq!(ack.get("id").as_u64(), Some(424242));
    // drain → draining + drained; reopen → reopened.
    s.write_all(b"{\"op\":\"drain\"}\n").unwrap();
    assert_eq!(
        Json::parse(&read_line(&mut r)).unwrap().get("type").as_str(),
        Some("draining")
    );
    assert_eq!(
        Json::parse(&read_line(&mut r)).unwrap().get("type").as_str(),
        Some("drained")
    );
    s.write_all(b"{\"op\":\"reopen\"}\n").unwrap();
    assert_eq!(
        Json::parse(&read_line(&mut r)).unwrap().get("type").as_str(),
        Some("reopened")
    );
    // fleet ops answer a typed error on a fleet-less server.
    s.write_all(b"{\"op\":\"fleet_stats\"}\n").unwrap();
    let err = Json::parse(&read_line(&mut r)).unwrap();
    assert_eq!(err.get("type").as_str(), Some("error"));
    server.shutdown();
}
