//! Loadgen battery: fixed-seed determinism of the open-loop arrival
//! schedule and the BENCH_server.json counters, and edge backpressure
//! under deliberate overload — typed `overload` to the client, zero
//! scheduler-queue growth, zero hung connections.

use dynabatch::config::presets::{cpu_host, tiny_real};
use dynabatch::config::PolicyKind;
use dynabatch::engine::sim::SimEngine;
use dynabatch::engine::{Engine, StepOutcome, StepPlan};
use dynabatch::loadgen::{
    run, schedule, schedule_hash, LoadgenConfig, LoadgenReport,
};
use dynabatch::request::RequestId;
use dynabatch::server::client::{Client, ClientError, GenOptions};
use dynabatch::server::{serve_replicas_with, EdgeConfig, Server};
use dynabatch::service::{ReplicaSet, RoutePolicy, ServiceBuilder};
use dynabatch::util::json::Json;
use dynabatch::workload::Arrival;
use std::sync::Arc;
use std::time::Duration;

/// Sim engine with a real wall cost per step, so a stream stays in
/// flight long enough for the edge cap to be observably occupied.
struct SlowEngine {
    inner: SimEngine,
    delay: Duration,
}

impl Engine for SlowEngine {
    fn step(&mut self, plan: &StepPlan, out: &mut StepOutcome)
            -> anyhow::Result<()> {
        std::thread::sleep(self.delay);
        self.inner.step(plan, out)
    }

    fn release(&mut self, id: RequestId) {
        self.inner.release(id);
    }

    fn max_batch(&self) -> u32 {
        self.inner.max_batch()
    }

    fn max_seq(&self) -> u32 {
        self.inner.max_seq()
    }

    fn label(&self) -> String {
        format!("slow({})", self.inner.label())
    }
}

fn tiny_edge_server(edge: EdgeConfig, step_delay_ms: u64) -> Arc<Server> {
    let set = ReplicaSet::build(1, RoutePolicy::LeastLoaded, |_| {
        ServiceBuilder::new(tiny_real(), cpu_host())
            .policy(PolicyKind::Combined)
            .d_sla(0.05)
            .eta_tokens(100_000)
            .engine(move || {
                Ok(Box::new(SlowEngine {
                    inner: SimEngine::new(&tiny_real(), &cpu_host()),
                    delay: Duration::from_millis(step_delay_ms),
                }) as Box<dyn Engine>)
            })
    })
    .unwrap();
    serve_replicas_with(set, "127.0.0.1:0", edge).unwrap()
}

/// The deterministic report sections as comparable strings (the
/// `timing` section is wall-clock and explicitly excluded — the same
/// split the CI double-run comparison uses).
fn deterministic_sections(r: &LoadgenReport, cfg: &LoadgenConfig)
                          -> (String, String, String) {
    let j = r.to_json(cfg);
    (
        j.get("config").to_string(),
        j.get("schedule").to_string(),
        j.get("results").to_string(),
    )
}

#[test]
fn same_seed_same_schedule_and_counters() {
    let cfg = LoadgenConfig {
        arrival: Arrival::Poisson { rate: 40.0 },
        duration_s: 1.0,
        seed: 7,
        max_new_tokens: 3,
        ..LoadgenConfig::default()
    };
    let a = run(&cfg).unwrap();
    let b = run(&cfg).unwrap();

    // Schedule: bit-identical across runs.
    assert_eq!(a.n_arrivals, b.n_arrivals);
    assert!(a.n_arrivals > 10, "rate 40 over 1s should arrive");
    assert_eq!(a.schedule_hash, b.schedule_hash);
    assert_eq!(a.first_at.to_bits(), b.first_at.to_bits());
    assert_eq!(a.last_at.to_bits(), b.last_at.to_bits());

    // A fully-absorbed run pins every outcome counter.
    for r in [&a, &b] {
        assert_eq!(r.launched, r.n_arrivals);
        assert_eq!(r.done, r.launched, "{r:?}");
        assert_eq!(r.connect_failed, 0);
        assert_eq!(r.local_capped, 0);
        assert_eq!(r.overloaded, 0);
        assert_eq!(r.errored, 0);
        assert_eq!(r.hung, 0);
        assert_eq!(r.e2e.n, r.done);
    }

    // The JSON sections CI compares are string-identical.
    assert_eq!(
        deterministic_sections(&a, &cfg),
        deterministic_sections(&b, &cfg)
    );

    // A different seed reshuffles the schedule.
    let c = run(&LoadgenConfig { seed: 8, ..cfg.clone() }).unwrap();
    assert_ne!(a.schedule_hash, c.schedule_hash);
}

#[test]
fn bursty_and_diurnal_schedules_are_seed_stable() {
    for arrival in [
        Arrival::Bursty { high: 60.0, low: 5.0, period: 0.5 },
        Arrival::Diurnal { mean: 30.0, amplitude: 0.6, period: 1.0 },
    ] {
        let s1 = schedule(&arrival, 3.0, 21).unwrap();
        let s2 = schedule(&arrival, 3.0, 21).unwrap();
        assert!(!s1.is_empty());
        assert_eq!(schedule_hash(&s1), schedule_hash(&s2));
        for w in s1.windows(2) {
            assert!(w[0] <= w[1], "schedule must be monotone");
        }
        assert!(*s1.last().unwrap() <= 3.0);
    }
}

#[test]
fn overload_sheds_typed_and_queues_never_grow() {
    // max_inflight 1: the second concurrent generate must shed at the
    // edge with the typed error, before the scheduler sees it. The
    // 2ms/step engine keeps A's 64-token stream in flight for the
    // whole assertion window.
    let server = tiny_edge_server(
        EdgeConfig { max_inflight: 1, ..EdgeConfig::default() },
        2,
    );
    let addr = server.local_addr.to_string();

    let mut a = Client::connect(&addr).unwrap();
    let mut b = Client::connect(&addr).unwrap();
    // A long-running stream occupies the single edge slot.
    let id_a = a.submit("occupy the edge", 64, &GenOptions::default())
        .unwrap();

    // B's generate is shed with the typed client error...
    let err = b
        .generate("shed me", 2)
        .expect_err("second stream must shed at the edge");
    assert_eq!(
        err.downcast_ref::<ClientError>(),
        Some(&ClientError::Overloaded),
        "want typed overload, got: {err:#}"
    );

    // ...and never reached the scheduler: no queue growth beyond A's
    // single request, the shed is counted at the edge, and B's
    // connection stays usable for admin ops.
    let stats = b.stats().unwrap();
    assert!(stats.waiting + stats.running <= 1, "queue grew: {stats:?}");
    assert!(stats.edge_sheds >= 1);
    assert_eq!(stats.edge_inflight, 1);

    // Drain A fully: the slot frees and B can now generate — nothing
    // is hung on either connection.
    let mut done = false;
    while !done {
        use dynabatch::server::client::ClientEvent;
        match a.next_event().unwrap() {
            ClientEvent::Done { id, .. } => {
                assert_eq!(id, id_a);
                done = true;
            }
            ClientEvent::Error { message, .. } => {
                panic!("stream A failed: {message}")
            }
            _ => {}
        }
    }
    let g = b.generate("after the drain", 2).unwrap();
    assert_eq!(g.n_tokens, 2);
    let stats = b.stats().unwrap();
    assert_eq!(stats.edge_inflight, 0);
    server.shutdown();
}

#[test]
fn loadgen_reports_sheds_without_hangs_under_tiny_edge() {
    // Self-hosted server with a 2-stream edge under a 200 qps burst:
    // some arrivals must shed, every one must resolve (no hangs), and
    // the arithmetic must close.
    let cfg = LoadgenConfig {
        arrival: Arrival::Poisson { rate: 200.0 },
        duration_s: 0.5,
        seed: 11,
        max_new_tokens: 8,
        edge: Some(EdgeConfig {
            max_inflight: 2,
            ..EdgeConfig::default()
        }),
        host_step_delay_ms: 2,
        ..LoadgenConfig::default()
    };
    let r = run(&cfg).unwrap();
    assert!(r.n_arrivals > 50, "{r:?}");
    assert_eq!(r.launched + r.local_capped + r.connect_failed,
               r.n_arrivals);
    assert_eq!(r.done + r.overloaded + r.errored + r.hung, r.launched);
    assert!(r.overloaded > 0, "tiny edge must shed: {r:?}");
    assert!(r.done > 0, "some streams must finish: {r:?}");
    assert_eq!(r.hung, 0, "no hung connections: {r:?}");
    assert!((r.shed_rate - r.overloaded as f64 / r.launched as f64)
                .abs() < 1e-12);
    // Report serializes and round-trips.
    let j = r.to_json(&cfg);
    assert!(Json::parse(&j.to_string()).is_ok());
}
