//! Cross-module integration tests over the simulated stack: scheduler ×
//! policies × kv × workload × metrics, including failure injection and
//! long-run invariants. (The PJRT path is covered in test_pjrt_engine.rs.)

use dynabatch::config::presets::*;
use dynabatch::config::{PolicyKind, PreemptMode, SchedulerConfig};
use dynabatch::driver::{run_loop, run_sim, SimScenario};
use dynabatch::engine::sim::SimEngine;
use dynabatch::engine::{Engine, StepOutcome, StepPlan};
use dynabatch::metrics::RunMetrics;
use dynabatch::request::Request;
use dynabatch::scheduler::Scheduler;
use dynabatch::sim::{Clock, VirtualClock};
use dynabatch::util::prop::check;
use dynabatch::workload::{Arrival, LengthDist, Workload};

fn scenario(policy: PolicyKind) -> SimScenario {
    let model = llama_65b();
    let hardware = node_for(&model);
    SimScenario {
        model,
        hardware,
        sched: SchedulerConfig { policy, ..SchedulerConfig::default() },
        workload: Workload {
            name: "it".into(),
            arrival: Arrival::AllAtOnce,
            prompt: LengthDist::around(68.4, 512),
            output: LengthDist::around(200.0, 512),
            n_requests: 150,
            seed: 99,
            prefix: None,
            length_mix: None,
        },
        eta_tokens_override: None,
        swap_tokens: 0,
    }
}

#[test]
fn every_policy_completes_every_request() {
    for policy in [
        PolicyKind::StaticGreedy { max: 256 },
        PolicyKind::StaticFixed { batch: 32 },
        PolicyKind::MemoryAware,
        PolicyKind::MemoryAwareExact,
        PolicyKind::SlaFeedback,
        PolicyKind::Combined,
    ] {
        let mut s = scenario(policy.clone());
        s.sched.d_sla = Some(0.06);
        let m = run_sim(&s).unwrap();
        assert_eq!(m.n_requests, 150, "{policy:?}");
        assert_eq!(m.n_finished, 150, "{policy:?}");
        assert!(m.throughput > 0.0);
    }
}

#[test]
fn deterministic_replay_same_seed() {
    let s = scenario(PolicyKind::Combined);
    let a = run_sim(&s).unwrap();
    let b = run_sim(&s).unwrap();
    assert_eq!(a.output_tokens, b.output_tokens);
    assert!((a.makespan - b.makespan).abs() < 1e-9);
    assert!((a.throughput - b.throughput).abs() < 1e-6);
    assert_eq!(a.preemptions, b.preemptions);
}

#[test]
fn poisson_vs_bursty_load_both_stable() {
    for arrival in [
        Arrival::Poisson { rate: 2.0 },
        Arrival::Bursty { high: 5.0, low: 0.5, period: 15.0 },
    ] {
        let mut s = scenario(PolicyKind::MemoryAware);
        s.workload.arrival = arrival;
        let m = run_sim(&s).unwrap();
        assert_eq!(m.n_finished, 150);
        assert!(m.ttft_mean >= 0.0);
    }
}

#[test]
fn swap_preemption_roundtrips_under_pressure() {
    let mut s = scenario(PolicyKind::StaticGreedy { max: 256 });
    s.sched.preempt = PreemptMode::Swap;
    s.eta_tokens_override = Some(8_000);
    s.swap_tokens = 1_000_000;
    let m = run_sim(&s).unwrap();
    assert_eq!(m.n_finished, 150);
    assert!(m.swaps > 0, "pressure must trigger swapping");
}

#[test]
fn zero_swap_space_falls_back_to_recompute() {
    let mut s = scenario(PolicyKind::StaticGreedy { max: 256 });
    s.sched.preempt = PreemptMode::Swap;
    s.eta_tokens_override = Some(8_000);
    s.swap_tokens = 0; // swap configured but no space
    let m = run_sim(&s).unwrap();
    assert_eq!(m.n_finished, 150);
    assert!(m.preemptions > 0, "must fall back to recompute");
}

#[test]
fn sla_feedback_controls_tbt_under_load() {
    // With a 50 ms SLA and heavy load, the combined policy's p95 decode
    // latency must sit near/below the SLA while static-greedy blows it.
    let mk = |policy| {
        let mut s = scenario(policy);
        s.sched.d_sla = Some(0.05);
        s.workload.n_requests = 400;
        run_sim(&s).unwrap()
    };
    let dynamic = mk(PolicyKind::Combined);
    let greedy = mk(PolicyKind::StaticGreedy { max: 256 });
    // The feedback loop holds the bulk of steps at/below the SLA (the tail
    // carries the binary search's exploration overshoot, cf. Alg. 2's ±α
    // window and eps_D tolerance).
    assert!(
        dynamic.tbt_p50 <= 0.060,
        "dynamic p50 {} must track the SLA within 20%",
        dynamic.tbt_p50
    );
    assert!(
        dynamic.tbt_mean <= 0.065,
        "dynamic mean {} must hug the SLA",
        dynamic.tbt_mean
    );
    assert!(
        greedy.tbt_p95 > dynamic.tbt_p95,
        "greedy ({}) should exceed dynamic ({})",
        greedy.tbt_p95,
        dynamic.tbt_p95
    );
}

#[test]
fn mid_run_burst_is_absorbed() {
    // Failure-injection-style load spike: a second wave arrives mid-run.
    let model = llama_65b();
    let hardware = node_for(&model);
    let eta = hardware.kv_budget(&model) / model.kv_bytes_per_token();
    let mut sched = Scheduler::new(
        SchedulerConfig {
            policy: PolicyKind::MemoryAware,
            ..SchedulerConfig::default()
        },
        eta, 0, 68.4, 200.0);
    let mut engine = SimEngine::new(&model, &hardware);
    let mut clock = VirtualClock::new();
    let mut reqs: Vec<Request> =
        (0..80).map(|i| Request::new(i, 64, 150, 0.0)).collect();
    reqs.extend((80..160).map(|i| Request::new(i, 64, 150, 5.0)));
    run_loop(&mut sched, &mut engine, &mut clock, reqs, 2_000_000).unwrap();
    assert_eq!(sched.finished().len(), 160);
    assert_eq!(sched.stats.preempt_recompute, 0,
               "Alg.1 absorbs the spike without thrash");
    sched.kv.check_invariants().unwrap();
}

#[test]
fn metrics_are_internally_consistent() {
    let m = run_sim(&scenario(PolicyKind::Combined)).unwrap();
    assert!(m.tbt_p50 <= m.tbt_p95 && m.tbt_p95 <= m.tbt_p99);
    assert!(m.total_tokens >= m.output_tokens);
    assert!(m.e2e_mean >= m.ttft_mean);
    let j = m.to_json().to_string();
    assert!(dynabatch::util::json::Json::parse(&j).is_ok());
}

/// Property: for random tight scenarios, (a) all requests finish, (b) KV
/// accounting balances, (c) dynamic never preempts more than greedy.
#[test]
fn prop_scheduler_invariants_random_scenarios() {
    check("scheduler invariants", 12, |g| {
        let eta = g.u64(4_000..=40_000);
        let n = g.usize(40..=120);
        let out_mean = g.f64(50.0, 400.0);
        let mk = |policy| {
            let mut s = scenario(policy);
            s.eta_tokens_override = Some(eta);
            s.workload.n_requests = n;
            s.workload.output = LengthDist::around(out_mean, 512);
            s.workload.seed = g_seed(&eta, &n);
            run_sim(&s).unwrap()
        };
        let dynamic = mk(PolicyKind::MemoryAware);
        let greedy = mk(PolicyKind::StaticGreedy { max: 256 });
        fn g_seed(a: &u64, b: &usize) -> u64 {
            a.wrapping_mul(31).wrapping_add(*b as u64)
        }
        dynamic.n_finished == n
            && greedy.n_finished == n
            && dynamic.preemptions <= greedy.preemptions
    });
}

#[test]
fn run_metrics_compute_empty_run() {
    let m = RunMetrics::compute("x".into(), &[],
                                &dynabatch::scheduler::SchedStats::default(),
                                &[], 0.0, None);
    assert_eq!(m.throughput, 0.0);
    assert_eq!(m.n_requests, 0);
}

/// Records each step's planned prefill tokens so tests can hold the
/// scheduler to the directive's chunk budget.
struct RecordingEngine {
    inner: SimEngine,
    last_prefill_tokens: u64,
}

impl Engine for RecordingEngine {
    fn step(&mut self, plan: &StepPlan, out: &mut StepOutcome)
            -> anyhow::Result<()> {
        self.last_prefill_tokens = plan.prefill_tokens();
        self.inner.step(plan, out)
    }

    fn release(&mut self, id: u64) {
        self.inner.release(id);
    }

    fn max_batch(&self) -> u32 {
        self.inner.max_batch()
    }

    fn max_seq(&self) -> u32 {
        self.inner.max_seq()
    }

    fn label(&self) -> String {
        self.inner.label()
    }
}

/// Satellite: the scheduler honors `Directive.prefill_chunk` end to end —
/// every fused step's prefill tokens fit the live budget, budgets shrink
/// under SLA pressure and grow when the engine has headroom.
#[test]
fn chunked_prefill_directives_adapt_and_are_honored() {
    let model = pangu_7b();
    let hardware = node_for(&model);
    // Returns the drained scheduler for directive-log inspection.
    let run = |d_sla: f64| {
        let cfg = SchedulerConfig {
            policy: PolicyKind::MemoryAware,
            chunk_tokens: Some(64),
            adaptive_chunk: true,
            d_sla: Some(d_sla),
            interval_steps: 1, // re-decide every step: dense directive log
            ..SchedulerConfig::default()
        };
        let mut engine = RecordingEngine {
            inner: SimEngine::new(&model, &hardware),
            last_prefill_tokens: 0,
        };
        let mut sched = Scheduler::new(cfg, 200_000, 0, 256.0, 64.0);
        let mut clock = VirtualClock::new();
        for i in 0..40 {
            sched.submit(Request::new(i, 256, 64, 0.0));
        }
        let mut guard = 0;
        while sched.has_work() && guard < 100_000 {
            match sched.step(&mut engine, clock.now()).unwrap() {
                Some(elapsed) => {
                    // The step that just ran was planned under the
                    // directive decided at its top.
                    let budget = sched
                        .current_directive()
                        .prefill_chunk
                        .expect("fused mode carries a chunk budget")
                        .max(1) as u64;
                    assert!(
                        engine.last_prefill_tokens <= budget,
                        "step moved {} prefill tokens over budget {budget}",
                        engine.last_prefill_tokens
                    );
                    clock.advance(elapsed);
                }
                None => break,
            }
            guard += 1;
        }
        assert_eq!(sched.finished().len(), 40);
        sched.kv.check_invariants().unwrap();
        sched
    };

    // Impossible SLA (1 ms): every decode sample is over budget, the
    // adaptive controller must shrink the chunk below its base.
    let tight = run(0.001);
    let budgets = |s: &Scheduler| -> Vec<u32> {
        s.directive_log
            .iter()
            .filter_map(|(_, d)| d.prefill_chunk)
            .collect()
    };
    let tb = budgets(&tight);
    assert!(!tb.is_empty());
    assert!(
        *tb.last().unwrap() < 64,
        "budget must shrink under pressure: {:?}",
        &tb[tb.len().saturating_sub(5)..]
    );

    // Unreachable SLA ceiling (10 s): constant headroom, the budget must
    // grow past its base.
    let loose = run(10.0);
    let lb = budgets(&loose);
    assert!(
        *lb.iter().max().unwrap() > 64,
        "budget must grow with headroom: max {:?}",
        lb.iter().max()
    );
}

#[test]
fn engine_trait_object_works() {
    // The scheduler must run over `dyn Engine` (the server path).
    let model = pangu_7b();
    let hardware = node_for(&model);
    let mut engine: Box<dyn Engine> =
        Box::new(SimEngine::new(&model, &hardware));
    let mut sched = Scheduler::new(SchedulerConfig::default(), 50_000, 0,
                                   32.0, 16.0);
    sched.submit(Request::new(1, 32, 4, 0.0));
    let mut now = 0.0;
    while sched.has_work() {
        if let Some(elapsed) = sched.step(engine.as_mut(), now).unwrap() {
            now += elapsed;
        }
    }
    assert_eq!(sched.finished().len(), 1);
}
