//! Steady-state allocation audit for the scheduler hot loop.
//!
//! A counting global allocator wraps the system allocator; after a
//! warmup phase (buffers grow to their steady capacity), a window of
//! pure decode steps must perform ZERO heap allocations: the plan,
//! outcome, report and scratch buffers are recycled, the trace rings are
//! preallocated, phase bookkeeping is pointer surgery inside the slab,
//! and the O(1) KV/telemetry aggregates are plain field updates.
//!
//! This file contains exactly one test: the counter is process-global,
//! so a concurrently running sibling test would pollute the window.

use dynabatch::config::presets::*;
use dynabatch::config::{PolicyKind, SchedulerConfig};
use dynabatch::engine::sim::SimEngine;
use dynabatch::request::Request;
use dynabatch::scheduler::Scheduler;
use dynabatch::sim::{Clock, VirtualClock};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout,
                      new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_decode_steps_do_not_allocate() {
    // 64 long-running requests under a fixed batch of 64: after
    // admission + prefill, every step is a full decode batch and nothing
    // finishes inside the measured window.
    let cfg = SchedulerConfig {
        policy: PolicyKind::StaticFixed { batch: 64 },
        // The prefix cache must not cost the decode hot path anything:
        // decode appends to private tail blocks and never touches the
        // tree, so the zero-allocation contract holds with it enabled.
        prefix_cache: true,
        // Bucketing on, too: the controller's per-interval decision
        // copies a precomputed `BucketPlan` (fixed arrays, `Copy`) into
        // the directive, the third intrusive index is pointer surgery
        // in the slab, and the padding charge is a plain field update —
        // none of it may allocate in steady state.
        buckets: 4,
        bucket_base: 64,
        padded_prefill: true,
        ..SchedulerConfig::default()
    };
    let m = pangu_7b();
    let hw = node_for(&m);
    let mut engine = SimEngine::new(&m, &hw);
    let mut sched = Scheduler::new(cfg, 10_000_000, 0, 32.0, 2000.0);
    let mut clock = VirtualClock::new();
    for i in 0..64 {
        // Budget far beyond the measured window (but within the
        // engine's max_seq) so nothing finishes mid-measurement.
        sched.submit(Request::new(i, 32, 2000, 0.0));
    }
    // Warmup: admission, prefill, buffer growth, ring fill-in, and at
    // least several controller decision intervals.
    for _ in 0..300 {
        let elapsed = sched
            .step(&mut engine, clock.now())
            .unwrap()
            .expect("work present");
        clock.advance(elapsed);
    }
    assert_eq!(sched.running_len(), 64, "batch must be in steady decode");

    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..256 {
        let elapsed = sched
            .step(&mut engine, clock.now())
            .unwrap()
            .expect("work present");
        clock.advance(elapsed);
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state decode steps must not allocate ({} allocations \
         across 256 steps)",
        after - before
    );
    // The loop was actually doing full-batch decode work the whole time.
    assert_eq!(sched.running_len(), 64);
    assert!(sched.stats.decode_steps >= 256);
}
