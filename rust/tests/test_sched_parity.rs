//! Data-layout parity suite for the hot-path overhaul.
//!
//! The slab + phase-index + O(1)-accounting scheduler must be
//! *behavior-preserving*: the quantities it maintains incrementally are
//! exactly what the old per-step scans computed. Three angles:
//!
//! 1. **Shadow parity** — `Scheduler::enable_shadow_checks` recomputes
//!    every incremental quantity (phase lists and counts, waiting
//!    deadlines, slab/index coherence, cached KV aggregates) from a full
//!    rescan at the top of *every* step — the scan-based semantics of
//!    the pre-overhaul hot path — and panics on any divergence. A run
//!    with shadow checks on must also produce metrics identical to the
//!    same run with them off (the instrumentation is read-only).
//! 2. **Fixed-seed determinism** — identical scenarios produce
//!    bit-identical `RunMetrics` JSON across repeated runs. This pins
//!    the golden regression record and guards against iteration-order
//!    leaks from the hashed boundary indexes (nothing on the step path
//!    may depend on `HashMap` iteration order).
//! 3. **Structural goldens** — invariant outcomes (every request
//!    finishes, exact token counts, preemption presence/absence per
//!    policy) that the old path satisfied by construction.

use dynabatch::config::presets::*;
use dynabatch::config::{PolicyKind, PreemptMode, SchedulerConfig};
use dynabatch::driver::{run_loop, run_sim, SimScenario};
use dynabatch::engine::sim::SimEngine;
use dynabatch::engine::Engine;
use dynabatch::metrics::RunMetrics;
use dynabatch::request::{PriorityClass, Request};
use dynabatch::scheduler::Scheduler;
use dynabatch::sim::{Clock, VirtualClock};
use dynabatch::workload::{Arrival, LengthDist, Workload};

fn scenario(policy: PolicyKind, n: usize) -> SimScenario {
    let model = pangu_7b();
    let hardware = node_for(&model);
    SimScenario {
        model,
        hardware,
        sched: SchedulerConfig {
            policy,
            d_sla: Some(0.05),
            ..SchedulerConfig::default()
        },
        workload: Workload {
            name: "parity".into(),
            arrival: Arrival::Poisson { rate: 20.0 },
            prompt: LengthDist::around(128.0, 1024),
            output: LengthDist::around(96.0, 1024),
            n_requests: n,
            seed: 7,
            prefix: None,
            length_mix: None,
        },
        eta_tokens_override: None,
        swap_tokens: 0,
    }
}

/// Run a scenario through the same wiring as `run_sim`, but on a
/// caller-configured scheduler (shadow checks, trace bounds).
fn run_manual(s: &SimScenario, shadow: bool) -> RunMetrics {
    let mut engine = SimEngine::new(&s.model, &s.hardware);
    let mut sched = Scheduler::new(
        s.sched.clone(),
        s.eta_tokens(),
        s.swap_tokens,
        s.workload.prompt.mean(),
        s.workload.output.mean(),
    );
    sched.retain_full_traces();
    if shadow {
        sched.enable_shadow_checks();
    }
    sched.telemetry.set_prior_variances(
        s.workload.prompt.variance(),
        s.workload.output.variance(),
    );
    let mut clock = VirtualClock::new();
    let requests = s.workload.generate();
    let max_steps = (requests.len() as u64 * 4096).max(1_000_000);
    run_loop(&mut sched, &mut engine, &mut clock, requests, max_steps)
        .unwrap();
    RunMetrics::compute(
        sched.controller_label(),
        sched.finished(),
        &sched.stats,
        &sched.decode_latencies.to_vec(),
        clock.now(),
        engine.utilization(),
    )
}

fn policies_under_test() -> Vec<(PolicyKind, &'static str)> {
    vec![
        (PolicyKind::MemoryAware, "alg1"),
        (PolicyKind::StaticGreedy { max: 256 }, "greedy"),
        (PolicyKind::SlaFeedback, "alg2"),
        (PolicyKind::Combined, "combined"),
    ]
}

#[test]
fn shadow_checked_run_matches_unshadowed() {
    for (policy, name) in policies_under_test() {
        let s = scenario(policy, 150);
        let plain = run_manual(&s, false);
        // Shadow mode re-derives the O(1) state from full scans every
        // step and panics on divergence; reaching the end means every
        // step's incremental accounting matched the rescan.
        let shadowed = run_manual(&s, true);
        assert_eq!(plain.to_json().to_string(),
                   shadowed.to_json().to_string(),
                   "{name}: shadow instrumentation changed behavior");
    }
}

#[test]
fn fixed_seed_runs_are_bit_identical() {
    for (policy, name) in policies_under_test() {
        let s = scenario(policy, 200);
        let a = run_sim(&s).unwrap().to_json().to_string();
        let b = run_sim(&s).unwrap().to_json().to_string();
        assert_eq!(a, b, "{name}: fixed-seed run not reproducible");
    }
}

#[test]
fn chunked_prefill_parity_under_shadow() {
    // PD-fusion mode exercises the prefill index hardest: partial
    // chunks, same-step fusion with decodes, phase flips mid-run.
    let mut s = scenario(PolicyKind::MemoryAware, 120);
    s.sched.chunk_tokens = Some(64);
    s.sched.adaptive_chunk = true;
    let plain = run_manual(&s, false);
    let shadowed = run_manual(&s, true);
    assert_eq!(plain.to_json().to_string(),
               shadowed.to_json().to_string());
    assert_eq!(shadowed.n_finished, 120, "every request completes");
}

#[test]
fn preemption_storm_parity_under_shadow() {
    // Tight η with greedy admission: constant recompute-preemption churn
    // (the worst case for run-list bookkeeping), plus the swap flavor.
    for preempt in [PreemptMode::Recompute, PreemptMode::Swap] {
        let mut s = scenario(PolicyKind::StaticGreedy { max: 256 }, 40);
        s.sched.preempt = preempt;
        s.workload.arrival = Arrival::AllAtOnce;
        // Same pressure ratio as the scheduler's own preemption tests:
        // peak demand ≈ 2× η, guaranteed thrash, guaranteed drain.
        s.workload.prompt = LengthDist::Fixed(64);
        s.workload.output = LengthDist::Fixed(128);
        s.eta_tokens_override = Some(4_000);
        s.swap_tokens = if preempt == PreemptMode::Swap { 100_000 } else { 0 };
        let plain = run_manual(&s, false);
        let shadowed = run_manual(&s, true);
        assert_eq!(plain.to_json().to_string(),
                   shadowed.to_json().to_string(),
                   "{preempt:?}");
        assert_eq!(shadowed.n_finished, 40, "{preempt:?}");
        assert!(shadowed.preemptions + shadowed.swaps > 0,
                "{preempt:?}: scenario must actually preempt");
    }
}

#[test]
fn mixed_lifecycle_stress_under_shadow() {
    // Everything at once: priority classes, deadlines that expire (shed),
    // an oversized reject, a zero-length prompt, and cancels mid-flight —
    // with shadow rescans validating every step.
    let model = pangu_7b();
    let hardware = node_for(&model);
    let cfg = SchedulerConfig {
        policy: PolicyKind::StaticFixed { batch: 4 },
        ..SchedulerConfig::default()
    };
    let mut engine = SimEngine::new(&model, &hardware);
    let mut sched = Scheduler::new(cfg, 100_000, 0, 64.0, 64.0);
    sched.enable_shadow_checks();
    let mut clock = VirtualClock::new();
    for i in 0..24u64 {
        let class = match i % 3 {
            0 => PriorityClass::Interactive,
            1 => PriorityClass::Standard,
            _ => PriorityClass::Batch,
        };
        let deadline = if i % 5 == 0 { Some(0.02) } else { None };
        sched.submit(Request::new(i, 64, 32, 0.0)
            .with_class(class)
            .with_deadline(deadline));
    }
    sched.submit(Request::new(100, 0, 4, 0.0)); // zero-length prompt
    sched.submit(Request::new(101, 4000, 10, 0.0)); // oversized → reject
    let mut steps = 0u64;
    while sched.has_work() && steps < 100_000 {
        if steps == 10 {
            sched.cancel(&mut engine, 3, clock.now());
            sched.cancel(&mut engine, 999, clock.now()); // unknown: no-op
        }
        match sched.step(&mut engine, clock.now()).unwrap() {
            Some(elapsed) => clock.advance(elapsed),
            None => break,
        }
        steps += 1;
    }
    assert_eq!(sched.finished().len(), 26, "every submission terminal");
    assert_eq!(sched.stats.rejected, 1);
    assert!(sched.stats.shed >= 1, "expired deadlines must shed");
    assert_eq!(sched.stats.cancelled, 1);
    assert_eq!(sched.kv.used_tokens(), 0);
    sched.kv.check_invariants().unwrap();
}

#[test]
fn catch_all_bucket_is_parity_with_unbucketed() {
    // `buckets: 1` degenerates every plan level to the catch-all
    // bucket: one prefill group per step, unlimited quota — exactly
    // the unbucketed semantics. The bucketed run must therefore
    // reproduce the plain run bit-for-bit (shadow checks additionally
    // re-verify the third intrusive index every step), with only the
    // controller label differing.
    for (policy, name) in policies_under_test() {
        let s = scenario(policy, 150);
        let plain = run_manual(&s, false);
        let mut b = s.clone();
        b.sched.buckets = 1;
        let mut bucketed = run_manual(&b, true);
        assert!(bucketed.policy.ends_with("+buckets"),
                "{name}: bucketing controller must be installed \
                 (label {})", bucketed.policy);
        bucketed.policy = plain.policy.clone();
        assert_eq!(plain.to_json().to_string(),
                   bucketed.to_json().to_string(),
                   "{name}: catch-all bucketing changed behavior");
    }
    // Same degenerate-plan parity through the chunked-prefill planner
    // (per-bucket budget consumption must reduce to the flat walk).
    let mut s = scenario(PolicyKind::MemoryAware, 120);
    s.sched.chunk_tokens = Some(64);
    s.sched.adaptive_chunk = true;
    let plain = run_manual(&s, false);
    s.sched.buckets = 1;
    let mut bucketed = run_manual(&s, true);
    bucketed.policy = plain.policy.clone();
    assert_eq!(plain.to_json().to_string(),
               bucketed.to_json().to_string(),
               "chunked: catch-all bucketing changed behavior");
}

#[test]
fn structural_goldens_fixed_workload() {
    // Fixed-distribution scenario with exact, derivable outcomes — the
    // invariants any behavior-preserving layout must reproduce.
    let mut s = scenario(PolicyKind::MemoryAware, 100);
    s.workload.arrival = Arrival::AllAtOnce;
    s.workload.prompt = LengthDist::Fixed(128);
    s.workload.output = LengthDist::Fixed(64);
    let m = run_sim(&s).unwrap();
    assert_eq!(m.n_requests, 100);
    assert_eq!(m.n_finished, 100);
    assert_eq!(m.output_tokens, 100 * 64);
    assert_eq!(m.total_tokens, 100 * (64 + 128));
    assert_eq!(m.preemptions, 0, "Alg.1 must respect the memory bound");
    assert_eq!(m.rejected, 0);
    assert_eq!(m.shed, 0);
    assert!(m.throughput > 0.0);
    assert!(m.tbt_p99 >= m.tbt_p50);
}
