//! Smoke coverage of the experiment harnesses + CLI surfaces at tiny
//! scales: every table/figure generator must run, render, and carry the
//! qualitative shape the paper claims.

use dynabatch::experiments::{ablations, figures, table2};

#[test]
fn fig3_sweep_renders_and_orders() {
    let pts = figures::fig3(500.0, 120);
    assert_eq!(pts.len(), 120);
    let md = figures::render_fig3(&pts).to_markdown();
    assert!(md.contains("Phi"));
    let anchors = figures::fig3_anchors(&pts);
    assert_eq!(anchors.len(), 2);
    assert!(anchors[0].1 <= anchors[1].1, "larger SLA → larger batch");
}

#[test]
fn fig2_render_has_sparkline_and_csv() {
    let r = figures::fig2(80).unwrap();
    let text = figures::render_fig2(&r);
    assert!(text.contains("utilization"));
    let csv = figures::fig2_csv(&r);
    assert!(csv.starts_with("t_s,used_tokens,capacity_tokens"));
    assert!(csv.lines().count() > 10);
}

#[test]
fn fig4_small_probe_runs() {
    let r = figures::fig4(80, &[]).unwrap();
    assert!(r.static_qps >= 0.0 && r.dynamic_qps >= 0.0);
    let txt = figures::render_fig4(&r);
    assert!(txt.contains("Fig. 4"));
}

#[test]
fn table2_render_shape() {
    // Tiny probes keep this affordable; mechanism checks live in the
    // driver/table tests.
    let rows = table2::run(0.05).unwrap();
    assert_eq!(rows.len(), 3);
    assert!(rows[2].pd_fusion);
    let md = table2::render(&rows).to_markdown();
    assert!(md.contains("Cap dyn"));
    for r in &rows {
        assert!(r.dynamic_cap.capacity_qps >= 0.0);
    }
}

#[test]
fn ablation_interval_and_alpha_tables() {
    let t = ablations::interval_sweep(60).unwrap();
    assert!(t.to_markdown().lines().count() >= 8);
    let t = ablations::alpha_delta_sweep(60).unwrap();
    assert!(t.to_markdown().contains("alpha"));
}
