//! Integration tests for the first-class service API and the v2 TCP
//! protocol over it: end-to-end submit → stream → done, priority-class
//! admission under a constrained b_t, cancellation that frees KV blocks
//! mid-flight (asserted via the KvBlockManager accounting the service
//! snapshot exposes), and the live control plane — `set_policy` hot-swaps
//! mid-stream, `stats`, and `drain`.

use dynabatch::config::presets::*;
use dynabatch::config::{PolicyKind, SchedulerConfig};
use dynabatch::engine::sim::SimEngine;
use dynabatch::engine::{Engine, StepOutcome, StepPlan};
use dynabatch::request::{PriorityClass, RequestId, SamplingParams};
use dynabatch::scheduler::Scheduler;
use dynabatch::server::client::{Client, ClientEvent, GenOptions};
use dynabatch::server::serve;
use dynabatch::service::{
    GenEvent, GenRequest, Service, ServiceBuilder, ServiceSnapshot,
    SubmitError,
};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Simulated engine with a real wall-clock cost per step, so mid-flight
/// control (cancel) has a deterministic window to land in.
struct SlowEngine {
    inner: SimEngine,
    delay: Duration,
}

impl SlowEngine {
    fn new(delay_ms: u64) -> Self {
        let model = tiny_real();
        let hw = cpu_host();
        SlowEngine {
            inner: SimEngine::new(&model, &hw),
            delay: Duration::from_millis(delay_ms),
        }
    }
}

impl Engine for SlowEngine {
    fn step(&mut self, plan: &StepPlan, out: &mut StepOutcome)
            -> anyhow::Result<()> {
        std::thread::sleep(self.delay);
        self.inner.step(plan, out)
    }

    fn release(&mut self, id: RequestId) {
        self.inner.release(id);
    }

    fn max_batch(&self) -> u32 {
        self.inner.max_batch()
    }

    fn max_seq(&self) -> u32 {
        self.inner.max_seq()
    }

    fn label(&self) -> String {
        format!("slow({})", self.inner.label())
    }
}

fn poll_snapshot<F: Fn(&ServiceSnapshot) -> bool>(service: &Service, ok: F,
                                                  what: &str)
                                                  -> ServiceSnapshot {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let snap = service.snapshot();
        if ok(&snap) {
            return snap;
        }
        assert!(Instant::now() < deadline,
                "timed out waiting for {what}: {snap:?}");
        std::thread::sleep(Duration::from_millis(2));
    }
}

// ---------------------------------------------------------------- service

#[test]
fn service_submit_stream_done() {
    let service = ServiceBuilder::new(tiny_real(), cpu_host())
        .policy(PolicyKind::Combined)
        .d_sla(0.05)
        .eta_tokens(100_000)
        .build()
        .unwrap();
    let mut handle = service
        .submit(
            GenRequest::from_text("stream me", 8)
                .with_class(PriorityClass::Interactive)
                .with_sampling(SamplingParams {
                    temperature: 0.2,
                    top_k: 16,
                    top_p: 0.9,
                    seed: Some(11),
                }),
        )
        .unwrap();

    // Event order: accepted, then tokens, then done — nothing else.
    let mut tokens = 0;
    let mut accepted = false;
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        assert!(Instant::now() < deadline, "stream stalled");
        let Some(ev) = handle.next_event_timeout(Duration::from_millis(100))
        else {
            continue;
        };
        match ev {
            GenEvent::Accepted { class, .. } => {
                assert!(!accepted && tokens == 0, "accepted comes first");
                assert_eq!(class, PriorityClass::Interactive);
                accepted = true;
            }
            GenEvent::Token { .. } => {
                assert!(accepted);
                tokens += 1;
            }
            GenEvent::Done { n_tokens, ttft, e2e, .. } => {
                assert!(accepted);
                assert_eq!(n_tokens, 8);
                assert_eq!(tokens, 8, "every token was streamed");
                assert!(e2e >= ttft && ttft >= 0.0);
                break;
            }
            other => panic!("unexpected event {other:?}"),
        }
    }
    assert!(handle.next_event_timeout(Duration::from_millis(50)).is_none(),
            "stream is over after the terminal event");
    service.shutdown();
}

#[test]
fn cancel_mid_stream_frees_kv_blocks() {
    let service = ServiceBuilder::new(tiny_real(), cpu_host())
        .policy(PolicyKind::MemoryAware)
        .eta_tokens(100_000)
        .engine(move || Ok(Box::new(SlowEngine::new(3)) as Box<dyn Engine>))
        .build()
        .unwrap();
    // 200 decode steps × 3 ms ≈ 600 ms of runway for the cancel.
    let mut handle = service
        .submit(GenRequest::from_text("cancel me", 200))
        .unwrap();

    // Wait until tokens are flowing (KV resident, decode in flight).
    let mut seen = 0;
    let deadline = Instant::now() + Duration::from_secs(10);
    while seen < 2 {
        assert!(Instant::now() < deadline, "no tokens streamed");
        match handle.next_event_timeout(Duration::from_millis(100)) {
            Some(GenEvent::Token { .. }) => seen += 1,
            Some(GenEvent::Accepted { .. }) | None => {}
            Some(other) => panic!("unexpected event {other:?}"),
        }
    }
    let snap = service.snapshot();
    assert!(snap.kv_used_tokens > 0, "KV must be resident mid-stream");

    handle.cancel();
    let mut cancelled = false;
    let deadline = Instant::now() + Duration::from_secs(10);
    while !cancelled {
        assert!(Instant::now() < deadline, "cancel never landed");
        match handle.next_event_timeout(Duration::from_millis(100)) {
            Some(GenEvent::Cancelled { .. }) => cancelled = true,
            Some(GenEvent::Token { .. }) | None => {} // in-flight steps
            Some(GenEvent::Done { .. }) => {
                panic!("request completed before cancel — widen the runway")
            }
            Some(other) => panic!("unexpected event {other:?}"),
        }
    }

    // The acceptance check: KvBlockManager accounting shows the blocks
    // came back.
    let snap = poll_snapshot(
        &service,
        |s| s.cancelled == 1 && s.kv_used_tokens == 0,
        "cancelled KV blocks to be freed",
    );
    assert_eq!(snap.kv_free_blocks, snap.kv_total_blocks);
    assert_eq!(snap.running, 0);
    service.shutdown();
}

#[test]
fn priority_class_wins_admission_under_tight_bt() {
    // b_t pinned to 1: whichever class wins admission runs alone. Start
    // paused so both submissions are queued before the first step.
    let cfg = SchedulerConfig {
        policy: PolicyKind::StaticFixed { batch: 1 },
        ..SchedulerConfig::default()
    };
    let service = ServiceBuilder::new(tiny_real(), cpu_host())
        .config(cfg)
        .eta_tokens(100_000)
        .paused(true)
        .build()
        .unwrap();
    // Batch-class first — arrival order must NOT decide.
    let low = service
        .submit(GenRequest::from_text("low priority", 16)
            .with_class(PriorityClass::Batch))
        .unwrap();
    let high = service
        .submit(GenRequest::from_text("high priority", 16)
            .with_class(PriorityClass::Interactive))
        .unwrap();
    poll_snapshot(&service, |s| s.waiting == 2, "both submissions queued");
    assert_eq!(
        poll_snapshot(&service, |s| s.waiting == 2, "queued").waiting_by_class,
        [1, 0, 1]
    );
    service.resume();

    let high_c = high.wait().unwrap();
    let low_c = low.wait().unwrap();
    assert_eq!(high_c.n_tokens, 16);
    assert_eq!(low_c.n_tokens, 16);
    // The interactive request drained completely before the batch one
    // was even admitted: its whole e2e fits inside the batch TTFT
    // (arrivals differ by at most the batch request's head start).
    assert!(
        low_c.ttft >= high_c.e2e,
        "interactive must fully preempt the batch slot: low ttft {} \
         vs high e2e {}",
        low_c.ttft, high_c.e2e
    );
    service.shutdown();
}

#[test]
fn deadline_shedding_surfaces_as_stream_error() {
    let cfg = SchedulerConfig {
        policy: PolicyKind::StaticFixed { batch: 1 },
        ..SchedulerConfig::default()
    };
    let service = ServiceBuilder::new(tiny_real(), cpu_host())
        .config(cfg)
        .eta_tokens(100_000)
        .engine(move || Ok(Box::new(SlowEngine::new(3)) as Box<dyn Engine>))
        .build()
        .unwrap();
    // Occupy the slot for ~600 ms; the second request only tolerates
    // 50 ms of queueing.
    let long = service
        .submit(GenRequest::from_text("occupier", 200))
        .unwrap();
    let doomed = service
        .submit(GenRequest::from_text("impatient", 4).with_deadline(0.05))
        .unwrap();
    let err = doomed.wait().unwrap_err();
    assert!(err.to_string().contains("deadline"), "{err}");
    poll_snapshot(&service, |s| s.shed == 1, "shed counter");
    long.cancel();
    service.shutdown();
}

// ---------------------------------------------------------- control plane

#[test]
fn drain_resolves_after_inflight_terminal_and_rejects_new() {
    let service = ServiceBuilder::new(tiny_real(), cpu_host())
        .policy(PolicyKind::MemoryAware)
        .eta_tokens(100_000)
        .engine(move || Ok(Box::new(SlowEngine::new(3)) as Box<dyn Engine>))
        .build()
        .unwrap();
    let service = Arc::new(service);
    // ~150 decode steps × 3 ms ≈ 450 ms of in-flight runway.
    let mut handle = service
        .submit(GenRequest::from_text("occupier", 150))
        .unwrap();
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut seen = 0;
    while seen < 2 {
        assert!(Instant::now() < deadline, "no tokens streamed");
        match handle.next_event_timeout(Duration::from_millis(100)) {
            Some(GenEvent::Token { .. }) => seen += 1,
            Some(GenEvent::Accepted { .. }) | None => {}
            Some(other) => panic!("unexpected event {other:?}"),
        }
    }

    let drained = Arc::new(AtomicBool::new(false));
    let drainer = {
        let service = service.clone();
        let drained = drained.clone();
        std::thread::spawn(move || {
            let r = service.drain();
            drained.store(true, Ordering::SeqCst);
            r
        })
    };
    let deadline = Instant::now() + Duration::from_secs(5);
    while !service.is_draining() {
        assert!(Instant::now() < deadline, "drain flag never set");
        std::thread::sleep(Duration::from_millis(1));
    }

    // New work is refused with the typed error while draining.
    let err = service
        .submit(GenRequest::from_text("too late", 4))
        .unwrap_err();
    assert_eq!(err.downcast_ref::<SubmitError>(),
               Some(&SubmitError::Draining));
    // The occupier is still mid-flight, so the drain cannot have
    // resolved yet.
    assert!(!drained.load(Ordering::SeqCst),
            "drain resolved with a request still in flight");

    // The in-flight request runs to its full budget — not dropped.
    let c = handle.wait().unwrap();
    assert_eq!(c.n_tokens, 150);
    drainer.join().unwrap().unwrap();
    assert!(drained.load(Ordering::SeqCst));
    let snap = poll_snapshot(
        &service,
        |s| s.draining && s.finished >= 1 && s.kv_used_tokens == 0,
        "post-drain snapshot",
    );
    assert_eq!(snap.running, 0);
    assert_eq!(snap.waiting, 0);
    service.shutdown();
}

// ------------------------------------------------------------------- TCP

#[test]
fn tcp_v1_generate_unchanged_and_v2_cancel_roundtrip() {
    let cfg = SchedulerConfig {
        policy: PolicyKind::Combined,
        d_sla: Some(0.05),
        ..SchedulerConfig::default()
    };
    let sched = Scheduler::new(cfg, 100_000, 0, 16.0, 8.0);
    let server = serve(
        move || Ok(Box::new(SlowEngine::new(2)) as Box<dyn Engine>),
        sched,
        "127.0.0.1:0",
    )
    .unwrap();
    let addr = server.local_addr.to_string();

    // 1. The v1 `generate` op works unchanged against the v2 server.
    let mut c1 = Client::connect(&addr).unwrap();
    let g = c1.generate("old client", 5).unwrap();
    assert_eq!(g.n_tokens, 5);
    assert_eq!(g.tokens.len(), 5);
    assert!(g.e2e_ms >= g.ttft_ms);

    // 2. v2: typed submit (class + sampling + deadline), streamed, then
    //    cancelled mid-flight from the same connection.
    let mut c2 = Client::connect(&addr).unwrap();
    let opts = GenOptions {
        class: PriorityClass::Interactive,
        deadline_ms: Some(60_000.0),
        sampling: Some(SamplingParams {
            temperature: 0.7,
            top_k: 40,
            top_p: 0.9,
            seed: Some(1),
        }),
    };
    let id = c2.submit("long running", 200, &opts).unwrap();
    let mut toks = 0;
    while toks < 2 {
        match c2.next_event().unwrap() {
            ClientEvent::Token { id: i, .. } => {
                assert_eq!(i, id);
                toks += 1;
            }
            other => panic!("unexpected event {other:?}"),
        }
    }
    c2.send_cancel(id).unwrap();
    let (mut got_cancelled, mut got_ack) = (false, false);
    while !(got_cancelled && got_ack) {
        match c2.next_event().unwrap() {
            ClientEvent::Cancelled { id: i } => {
                assert_eq!(i, id);
                got_cancelled = true;
            }
            ClientEvent::CancelAck { id: i, enqueued } => {
                assert_eq!(i, id);
                assert!(enqueued);
                got_ack = true;
            }
            ClientEvent::Token { .. } => {} // steps already in flight
            other => panic!("unexpected event {other:?}"),
        }
    }

    // 3. Server-side KV accounting confirms the cancel freed the blocks.
    let snap = poll_snapshot(
        server.service(),
        |s| s.cancelled >= 1 && s.kv_used_tokens == 0,
        "server-side KV release",
    );
    assert_eq!(snap.kv_free_blocks, snap.kv_total_blocks);
    server.shutdown();
}

/// Acceptance: hot-swap StaticFixed → Combined on a live service
/// mid-stream via the v2 `set_policy` op. (a) the in-flight request is
/// not dropped — it streams to its full budget; (b) the next `stats`
/// snapshot reports the new controller label and a changed b_t.
#[test]
fn tcp_set_policy_hot_swaps_mid_stream() {
    let cfg = SchedulerConfig {
        policy: PolicyKind::StaticFixed { batch: 7 },
        d_sla: Some(0.05),
        ..SchedulerConfig::default()
    };
    let sched = Scheduler::new(cfg, 100_000, 0, 16.0, 8.0);
    let server = serve(
        move || Ok(Box::new(SlowEngine::new(2)) as Box<dyn Engine>),
        sched,
        "127.0.0.1:0",
    )
    .unwrap();
    let mut c = Client::connect(&server.local_addr.to_string()).unwrap();

    // One long-running stream: ~200 steps × 2 ms of runway.
    let id = c.submit("stays alive across the swap", 200,
                      &GenOptions::default()).unwrap();
    let mut tokens = 0u32;
    while tokens < 2 {
        match c.next_event().unwrap() {
            ClientEvent::Token { id: i, .. } => {
                assert_eq!(i, id);
                tokens += 1;
            }
            other => panic!("unexpected event {other:?}"),
        }
    }
    // Pre-swap stats: the fixed controller and its b_t.
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let s = c.stats().unwrap();
        if s.b_t == 7 {
            assert_eq!(s.controller, "static-fixed:7");
            assert_eq!(s.reconfigs, 0);
            break;
        }
        assert!(Instant::now() < deadline, "b_t never reached 7: {s:?}");
        std::thread::sleep(Duration::from_millis(2));
    }

    // Hot-swap. The returned label is the new controller's.
    let label = c.set_policy("combined").unwrap();
    assert_eq!(label, "combined(min(alg1,alg2))");
    // Unknown / invalid policies are rejected without killing anything.
    assert!(c.set_policy("bogus").is_err());
    assert!(c.set_policy("static-fixed:0").is_err());

    // (b) the next stats report the new controller and a changed b_t
    // (min(alg1,alg2) with one running decode settles at b_min = 1).
    let deadline = Instant::now() + Duration::from_secs(10);
    let stats = loop {
        let s = c.stats().unwrap();
        if s.controller == "combined(min(alg1,alg2))" && s.b_t != 7 {
            break s;
        }
        assert!(Instant::now() < deadline, "swap never observed: {s:?}");
        std::thread::sleep(Duration::from_millis(2));
    };
    assert_eq!(stats.reconfigs, 1);

    // (a) the stream survives the swap and completes its full budget.
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        assert!(Instant::now() < deadline, "stream stalled after swap");
        match c.next_event().unwrap() {
            ClientEvent::Token { id: i, .. } => {
                assert_eq!(i, id);
                tokens += 1;
            }
            ClientEvent::Done { id: i, n_tokens, .. } => {
                assert_eq!(i, id);
                assert_eq!(n_tokens, 200, "request lost tokens in swap");
                assert_eq!(tokens, 200, "every token was streamed");
                break;
            }
            other => panic!("unexpected event {other:?}"),
        }
    }
    server.shutdown();
}

/// Acceptance: `drain` stops admissions (typed connection error on any
/// connection), keeps `stats` live meanwhile, and announces `drained`
/// only after every in-flight request reached a terminal event.
#[test]
fn tcp_drain_rejects_new_work_and_resolves() {
    let cfg = SchedulerConfig {
        policy: PolicyKind::MemoryAware,
        ..SchedulerConfig::default()
    };
    let sched = Scheduler::new(cfg, 100_000, 0, 16.0, 8.0);
    let server = serve(
        move || Ok(Box::new(SlowEngine::new(2)) as Box<dyn Engine>),
        sched,
        "127.0.0.1:0",
    )
    .unwrap();
    let addr = server.local_addr.to_string();
    let mut c1 = Client::connect(&addr).unwrap();

    let id = c1.submit("drain me gently", 100, &GenOptions::default())
        .unwrap();
    let mut tokens = 0u32;
    while tokens < 2 {
        match c1.next_event().unwrap() {
            ClientEvent::Token { id: i, .. } => {
                assert_eq!(i, id);
                tokens += 1;
            }
            other => panic!("unexpected event {other:?}"),
        }
    }

    // Drain from a second connection (blocks until resolved).
    let drainer = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            let mut c2 = Client::connect(&addr).unwrap();
            c2.drain()
        })
    };
    // Admissions stop on every connection while the drain is pending.
    let deadline = Instant::now() + Duration::from_secs(5);
    while !c1.stats().unwrap().draining {
        assert!(Instant::now() < deadline, "draining flag never seen");
        std::thread::sleep(Duration::from_millis(1));
    }
    let err = c1.submit("rejected", 4, &GenOptions::default()).unwrap_err();
    assert!(err.to_string().contains("draining"), "{err}");

    // The in-flight stream still completes its full budget.
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        assert!(Instant::now() < deadline, "stream stalled during drain");
        match c1.next_event().unwrap() {
            ClientEvent::Token { id: i, .. } => assert_eq!(i, id),
            ClientEvent::Done { id: i, n_tokens, .. } => {
                assert_eq!(i, id);
                assert_eq!(n_tokens, 100);
                break;
            }
            other => panic!("unexpected event {other:?}"),
        }
    }
    drainer.join().unwrap().unwrap();
    let stats = c1.stats().unwrap();
    assert!(stats.draining);
    assert_eq!(stats.running, 0);
    assert_eq!(stats.kv_used_tokens, 0);
    assert!(stats.finished >= 1);
    server.shutdown();
}

#[test]
fn tcp_priority_classes_interleave() {
    // Two classes over TCP under a tight b_t: interactive finishes with
    // lower queueing delay than batch, and both complete.
    let cfg = SchedulerConfig {
        policy: PolicyKind::StaticFixed { batch: 1 },
        ..SchedulerConfig::default()
    };
    let sched = Scheduler::new(cfg, 100_000, 0, 16.0, 8.0);
    let server = serve(
        move || Ok(Box::new(SlowEngine::new(2)) as Box<dyn Engine>),
        sched,
        "127.0.0.1:0",
    )
    .unwrap();
    let addr = server.local_addr.to_string();

    let mut threads = Vec::new();
    for (class, n) in [
        (PriorityClass::Batch, 4),
        (PriorityClass::Interactive, 4),
    ] {
        let addr = addr.clone();
        threads.push(std::thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            let opts = GenOptions { class, ..Default::default() };
            let mut ttfts = Vec::new();
            for _ in 0..n {
                let g = c.generate_with("fair share", 12, &opts).unwrap();
                assert_eq!(g.n_tokens, 12);
                ttfts.push(g.ttft_ms);
            }
            (class, ttfts)
        }));
    }
    for t in threads {
        let (_, ttfts) = t.join().unwrap();
        assert_eq!(ttfts.len(), 4, "no class is starved");
    }
    server.shutdown();
}
