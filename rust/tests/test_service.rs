//! Integration tests for the first-class service API and the v2 TCP
//! protocol over it: end-to-end submit → stream → done, priority-class
//! admission under a constrained b_t, and cancellation that frees KV
//! blocks mid-flight (asserted via the KvBlockManager accounting the
//! service snapshot exposes).

use dynabatch::config::presets::*;
use dynabatch::config::{PolicyKind, SchedulerConfig};
use dynabatch::engine::sim::SimEngine;
use dynabatch::engine::{Engine, StepOutcome, StepPlan};
use dynabatch::request::{PriorityClass, RequestId, SamplingParams};
use dynabatch::scheduler::Scheduler;
use dynabatch::server::client::{Client, ClientEvent, GenOptions};
use dynabatch::server::serve;
use dynabatch::service::{
    GenEvent, GenRequest, Service, ServiceBuilder, ServiceSnapshot,
};
use std::time::{Duration, Instant};

/// Simulated engine with a real wall-clock cost per step, so mid-flight
/// control (cancel) has a deterministic window to land in.
struct SlowEngine {
    inner: SimEngine,
    delay: Duration,
}

impl SlowEngine {
    fn new(delay_ms: u64) -> Self {
        let model = tiny_real();
        let hw = cpu_host();
        SlowEngine {
            inner: SimEngine::new(&model, &hw),
            delay: Duration::from_millis(delay_ms),
        }
    }
}

impl Engine for SlowEngine {
    fn step(&mut self, plan: &StepPlan) -> anyhow::Result<StepOutcome> {
        std::thread::sleep(self.delay);
        self.inner.step(plan)
    }

    fn release(&mut self, id: RequestId) {
        self.inner.release(id);
    }

    fn max_batch(&self) -> u32 {
        self.inner.max_batch()
    }

    fn max_seq(&self) -> u32 {
        self.inner.max_seq()
    }

    fn label(&self) -> String {
        format!("slow({})", self.inner.label())
    }
}

fn poll_snapshot<F: Fn(&ServiceSnapshot) -> bool>(service: &Service, ok: F,
                                                  what: &str)
                                                  -> ServiceSnapshot {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let snap = service.snapshot();
        if ok(&snap) {
            return snap;
        }
        assert!(Instant::now() < deadline,
                "timed out waiting for {what}: {snap:?}");
        std::thread::sleep(Duration::from_millis(2));
    }
}

// ---------------------------------------------------------------- service

#[test]
fn service_submit_stream_done() {
    let service = ServiceBuilder::new(tiny_real(), cpu_host())
        .policy(PolicyKind::Combined)
        .d_sla(0.05)
        .eta_tokens(100_000)
        .build()
        .unwrap();
    let mut handle = service
        .submit(
            GenRequest::from_text("stream me", 8)
                .with_class(PriorityClass::Interactive)
                .with_sampling(SamplingParams {
                    temperature: 0.2,
                    top_k: 16,
                    top_p: 0.9,
                    seed: Some(11),
                }),
        )
        .unwrap();

    // Event order: accepted, then tokens, then done — nothing else.
    let mut tokens = 0;
    let mut accepted = false;
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        assert!(Instant::now() < deadline, "stream stalled");
        let Some(ev) = handle.next_event_timeout(Duration::from_millis(100))
        else {
            continue;
        };
        match ev {
            GenEvent::Accepted { class, .. } => {
                assert!(!accepted && tokens == 0, "accepted comes first");
                assert_eq!(class, PriorityClass::Interactive);
                accepted = true;
            }
            GenEvent::Token { .. } => {
                assert!(accepted);
                tokens += 1;
            }
            GenEvent::Done { n_tokens, ttft, e2e, .. } => {
                assert!(accepted);
                assert_eq!(n_tokens, 8);
                assert_eq!(tokens, 8, "every token was streamed");
                assert!(e2e >= ttft && ttft >= 0.0);
                break;
            }
            other => panic!("unexpected event {other:?}"),
        }
    }
    assert!(handle.next_event_timeout(Duration::from_millis(50)).is_none(),
            "stream is over after the terminal event");
    service.shutdown();
}

#[test]
fn cancel_mid_stream_frees_kv_blocks() {
    let service = ServiceBuilder::new(tiny_real(), cpu_host())
        .policy(PolicyKind::MemoryAware)
        .eta_tokens(100_000)
        .engine(move || Ok(Box::new(SlowEngine::new(3)) as Box<dyn Engine>))
        .build()
        .unwrap();
    // 200 decode steps × 3 ms ≈ 600 ms of runway for the cancel.
    let mut handle = service
        .submit(GenRequest::from_text("cancel me", 200))
        .unwrap();

    // Wait until tokens are flowing (KV resident, decode in flight).
    let mut seen = 0;
    let deadline = Instant::now() + Duration::from_secs(10);
    while seen < 2 {
        assert!(Instant::now() < deadline, "no tokens streamed");
        match handle.next_event_timeout(Duration::from_millis(100)) {
            Some(GenEvent::Token { .. }) => seen += 1,
            Some(GenEvent::Accepted { .. }) | None => {}
            Some(other) => panic!("unexpected event {other:?}"),
        }
    }
    let snap = service.snapshot();
    assert!(snap.kv_used_tokens > 0, "KV must be resident mid-stream");

    handle.cancel();
    let mut cancelled = false;
    let deadline = Instant::now() + Duration::from_secs(10);
    while !cancelled {
        assert!(Instant::now() < deadline, "cancel never landed");
        match handle.next_event_timeout(Duration::from_millis(100)) {
            Some(GenEvent::Cancelled { .. }) => cancelled = true,
            Some(GenEvent::Token { .. }) | None => {} // in-flight steps
            Some(GenEvent::Done { .. }) => {
                panic!("request completed before cancel — widen the runway")
            }
            Some(other) => panic!("unexpected event {other:?}"),
        }
    }

    // The acceptance check: KvBlockManager accounting shows the blocks
    // came back.
    let snap = poll_snapshot(
        &service,
        |s| s.cancelled == 1 && s.kv_used_tokens == 0,
        "cancelled KV blocks to be freed",
    );
    assert_eq!(snap.kv_free_blocks, snap.kv_total_blocks);
    assert_eq!(snap.running, 0);
    service.shutdown();
}

#[test]
fn priority_class_wins_admission_under_tight_bt() {
    // b_t pinned to 1: whichever class wins admission runs alone. Start
    // paused so both submissions are queued before the first step.
    let cfg = SchedulerConfig {
        policy: PolicyKind::StaticFixed { batch: 1 },
        ..SchedulerConfig::default()
    };
    let service = ServiceBuilder::new(tiny_real(), cpu_host())
        .config(cfg)
        .eta_tokens(100_000)
        .paused(true)
        .build()
        .unwrap();
    // Batch-class first — arrival order must NOT decide.
    let low = service
        .submit(GenRequest::from_text("low priority", 16)
            .with_class(PriorityClass::Batch))
        .unwrap();
    let high = service
        .submit(GenRequest::from_text("high priority", 16)
            .with_class(PriorityClass::Interactive))
        .unwrap();
    poll_snapshot(&service, |s| s.waiting == 2, "both submissions queued");
    assert_eq!(
        poll_snapshot(&service, |s| s.waiting == 2, "queued").waiting_by_class,
        [1, 0, 1]
    );
    service.resume();

    let high_c = high.wait().unwrap();
    let low_c = low.wait().unwrap();
    assert_eq!(high_c.n_tokens, 16);
    assert_eq!(low_c.n_tokens, 16);
    // The interactive request drained completely before the batch one
    // was even admitted: its whole e2e fits inside the batch TTFT
    // (arrivals differ by at most the batch request's head start).
    assert!(
        low_c.ttft >= high_c.e2e,
        "interactive must fully preempt the batch slot: low ttft {} \
         vs high e2e {}",
        low_c.ttft, high_c.e2e
    );
    service.shutdown();
}

#[test]
fn deadline_shedding_surfaces_as_stream_error() {
    let cfg = SchedulerConfig {
        policy: PolicyKind::StaticFixed { batch: 1 },
        ..SchedulerConfig::default()
    };
    let service = ServiceBuilder::new(tiny_real(), cpu_host())
        .config(cfg)
        .eta_tokens(100_000)
        .engine(move || Ok(Box::new(SlowEngine::new(3)) as Box<dyn Engine>))
        .build()
        .unwrap();
    // Occupy the slot for ~600 ms; the second request only tolerates
    // 50 ms of queueing.
    let long = service
        .submit(GenRequest::from_text("occupier", 200))
        .unwrap();
    let doomed = service
        .submit(GenRequest::from_text("impatient", 4).with_deadline(0.05))
        .unwrap();
    let err = doomed.wait().unwrap_err();
    assert!(err.to_string().contains("deadline"), "{err}");
    poll_snapshot(&service, |s| s.shed == 1, "shed counter");
    long.cancel();
    service.shutdown();
}

// ------------------------------------------------------------------- TCP

#[test]
fn tcp_v1_generate_unchanged_and_v2_cancel_roundtrip() {
    let cfg = SchedulerConfig {
        policy: PolicyKind::Combined,
        d_sla: Some(0.05),
        ..SchedulerConfig::default()
    };
    let sched = Scheduler::new(cfg, 100_000, 0, 16.0, 8.0);
    let server = serve(
        move || Ok(Box::new(SlowEngine::new(2)) as Box<dyn Engine>),
        sched,
        "127.0.0.1:0",
    )
    .unwrap();
    let addr = server.local_addr.to_string();

    // 1. The v1 `generate` op works unchanged against the v2 server.
    let mut c1 = Client::connect(&addr).unwrap();
    let g = c1.generate("old client", 5).unwrap();
    assert_eq!(g.n_tokens, 5);
    assert_eq!(g.tokens.len(), 5);
    assert!(g.e2e_ms >= g.ttft_ms);

    // 2. v2: typed submit (class + sampling + deadline), streamed, then
    //    cancelled mid-flight from the same connection.
    let mut c2 = Client::connect(&addr).unwrap();
    let opts = GenOptions {
        class: PriorityClass::Interactive,
        deadline_ms: Some(60_000.0),
        sampling: Some(SamplingParams {
            temperature: 0.7,
            top_k: 40,
            top_p: 0.9,
            seed: Some(1),
        }),
    };
    let id = c2.submit("long running", 200, &opts).unwrap();
    let mut toks = 0;
    while toks < 2 {
        match c2.next_event().unwrap() {
            ClientEvent::Token { id: i, .. } => {
                assert_eq!(i, id);
                toks += 1;
            }
            other => panic!("unexpected event {other:?}"),
        }
    }
    c2.send_cancel(id).unwrap();
    let (mut got_cancelled, mut got_ack) = (false, false);
    while !(got_cancelled && got_ack) {
        match c2.next_event().unwrap() {
            ClientEvent::Cancelled { id: i } => {
                assert_eq!(i, id);
                got_cancelled = true;
            }
            ClientEvent::CancelAck { id: i, enqueued } => {
                assert_eq!(i, id);
                assert!(enqueued);
                got_ack = true;
            }
            ClientEvent::Token { .. } => {} // steps already in flight
            other => panic!("unexpected event {other:?}"),
        }
    }

    // 3. Server-side KV accounting confirms the cancel freed the blocks.
    let snap = poll_snapshot(
        server.service(),
        |s| s.cancelled >= 1 && s.kv_used_tokens == 0,
        "server-side KV release",
    );
    assert_eq!(snap.kv_free_blocks, snap.kv_total_blocks);
    server.shutdown();
}

#[test]
fn tcp_priority_classes_interleave() {
    // Two classes over TCP under a tight b_t: interactive finishes with
    // lower queueing delay than batch, and both complete.
    let cfg = SchedulerConfig {
        policy: PolicyKind::StaticFixed { batch: 1 },
        ..SchedulerConfig::default()
    };
    let sched = Scheduler::new(cfg, 100_000, 0, 16.0, 8.0);
    let server = serve(
        move || Ok(Box::new(SlowEngine::new(2)) as Box<dyn Engine>),
        sched,
        "127.0.0.1:0",
    )
    .unwrap();
    let addr = server.local_addr.to_string();

    let mut threads = Vec::new();
    for (class, n) in [
        (PriorityClass::Batch, 4),
        (PriorityClass::Interactive, 4),
    ] {
        let addr = addr.clone();
        threads.push(std::thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            let opts = GenOptions { class, ..Default::default() };
            let mut ttfts = Vec::new();
            for _ in 0..n {
                let g = c.generate_with("fair share", 12, &opts).unwrap();
                assert_eq!(g.n_tokens, 12);
                ttfts.push(g.ttft_ms);
            }
            (class, ttfts)
        }));
    }
    for t in threads {
        let (_, ttfts) = t.join().unwrap();
        assert_eq!(ttfts.len(), 4, "no class is starved");
    }
    server.shutdown();
}
