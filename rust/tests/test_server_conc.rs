//! Concurrency regressions for the event-loop serving edge: a slow
//! reader must not block other connections, a mid-stream disconnect
//! must free the request through the cancel path (KV accounting
//! asserted), and a burst of concurrent connects/submits/cancels under
//! `serve_replicas` must lose nothing.

use dynabatch::config::presets::{cpu_host, tiny_real};
use dynabatch::config::PolicyKind;
use dynabatch::engine::sim::SimEngine;
use dynabatch::engine::{Engine, StepOutcome, StepPlan};
use dynabatch::request::RequestId;
use dynabatch::server::client::{Client, ClientEvent, GenOptions};
use dynabatch::server::{serve_replicas_with, EdgeConfig, Server};
use dynabatch::service::{ReplicaSet, RoutePolicy, ServiceBuilder};
use dynabatch::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Sim engine with a real wall cost per step: streams stay in flight
/// long enough for the concurrency windows under test to be real.
struct SlowEngine {
    inner: SimEngine,
    delay: Duration,
}

impl Engine for SlowEngine {
    fn step(&mut self, plan: &StepPlan, out: &mut StepOutcome)
            -> anyhow::Result<()> {
        std::thread::sleep(self.delay);
        self.inner.step(plan, out)
    }

    fn release(&mut self, id: RequestId) {
        self.inner.release(id);
    }

    fn max_batch(&self) -> u32 {
        self.inner.max_batch()
    }

    fn max_seq(&self) -> u32 {
        self.inner.max_seq()
    }

    fn label(&self) -> String {
        format!("slow({})", self.inner.label())
    }
}

fn paced_server(replicas: usize, step_delay_ms: u64) -> Arc<Server> {
    let set = ReplicaSet::build(replicas, RoutePolicy::LeastLoaded, |_| {
        ServiceBuilder::new(tiny_real(), cpu_host())
            .policy(PolicyKind::Combined)
            .d_sla(0.05)
            .eta_tokens(100_000)
            .engine(move || {
                Ok(Box::new(SlowEngine {
                    inner: SimEngine::new(&tiny_real(), &cpu_host()),
                    delay: Duration::from_millis(step_delay_ms),
                }) as Box<dyn Engine>)
            })
    })
    .unwrap();
    serve_replicas_with(set, "127.0.0.1:0", EdgeConfig::default()).unwrap()
}

/// Poll the server until `pred` holds or the deadline passes; returns
/// the last observed stats either way.
fn poll_stats(
    addr: &str,
    timeout: Duration,
    pred: impl Fn(&dynabatch::server::client::ServerStats) -> bool,
) -> dynabatch::server::client::ServerStats {
    let mut c = Client::connect(addr).unwrap();
    let deadline = Instant::now() + timeout;
    loop {
        let s = c.stats().unwrap();
        if pred(&s) || Instant::now() >= deadline {
            return s;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
}

#[test]
fn slow_reader_does_not_block_other_connections() {
    let server = paced_server(1, 2);
    let addr = server.local_addr.to_string();

    // A is a deliberately slow reader: it submits a long stream and
    // then never touches its socket, so the server keeps buffering
    // frames for it while the event loop serves everyone else.
    let mut a = TcpStream::connect(&addr).unwrap();
    a.write_all(
        b"{\"op\":\"generate\",\"prompt\":\"slow reader\",\
          \"max_new_tokens\":64}\n",
    )
    .unwrap();
    a.flush().unwrap();

    // B must stream to completion while A is stalled.
    let t0 = Instant::now();
    let mut b = Client::connect(&addr).unwrap();
    let g = b.generate("unblocked neighbor", 4).unwrap();
    assert_eq!(g.n_tokens, 4);
    assert!(
        t0.elapsed() < Duration::from_secs(20),
        "B took {:?} behind a slow reader",
        t0.elapsed()
    );

    // A's frames were buffered, not dropped: once it finally reads, the
    // accepted frame (and the rest of its stream) is all there.
    let mut lines = BufReader::new(a).lines();
    let first = lines.next().unwrap().unwrap();
    let j = Json::parse(&first).unwrap();
    assert_eq!(j.get("type").as_str(), Some("accepted"));
    let mut saw_done = false;
    for line in lines {
        let j = Json::parse(&line.unwrap()).unwrap();
        if j.get("type").as_str() == Some("done") {
            saw_done = true;
            break;
        }
    }
    assert!(saw_done, "slow reader's stream must still finish");
    server.shutdown();
}

#[test]
fn disconnect_mid_stream_frees_request_and_kv() {
    let server = paced_server(1, 2);
    let addr = server.local_addr.to_string();

    // Raw connection: submit a long stream, read the accepted frame so
    // the request is provably in flight, then vanish.
    {
        let mut a = TcpStream::connect(&addr).unwrap();
        a.write_all(
            b"{\"op\":\"generate\",\"prompt\":\"goodbye cruel world\",\
              \"max_new_tokens\":200}\n",
        )
        .unwrap();
        a.flush().unwrap();
        let mut r = BufReader::new(&mut a);
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap();
        assert_eq!(j.get("type").as_str(), Some("accepted"));
        // Dropping the stream closes the socket mid-stream.
    }

    // The reaper must route the orphan through the cancel path: the
    // request leaves the running set and every KV block frees.
    let s = poll_stats(&addr, Duration::from_secs(20), |s| {
        s.running == 0 && s.waiting == 0 && s.kv_used_tokens == 0
    });
    assert_eq!(s.running, 0, "request leaked after disconnect: {s:?}");
    assert_eq!(s.waiting, 0, "{s:?}");
    assert_eq!(s.kv_used_tokens, 0, "KV leaked after disconnect: {s:?}");
    assert!(s.cancelled >= 1, "disconnect must count a cancel: {s:?}");
    server.shutdown();
}

#[test]
fn concurrent_connect_submit_cancel_burst_loses_nothing() {
    let server = paced_server(2, 1);
    let addr = server.local_addr.to_string();
    let n_threads = 12;

    let handles: Vec<_> = (0..n_threads)
        .map(|i| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                let id = c
                    .submit(&format!("burst {i}"), 16,
                            &GenOptions::default())
                    .unwrap();
                // Every third connection cancels its own stream while
                // it is (probably) still decoding.
                if i % 3 == 0 {
                    c.send_cancel(id).unwrap();
                }
                // Either way the stream MUST end with a terminal event.
                loop {
                    match c.next_event().unwrap() {
                        ClientEvent::Done { id: did, .. } => {
                            assert_eq!(did, id);
                            return "done";
                        }
                        ClientEvent::Cancelled { id: cid } => {
                            assert_eq!(cid, id);
                            return "cancelled";
                        }
                        ClientEvent::Error { .. } => return "error",
                        _ => {}
                    }
                }
            })
        })
        .collect();

    let mut done = 0;
    let mut cancelled = 0;
    for h in handles {
        match h.join().unwrap() {
            "done" => done += 1,
            "cancelled" => cancelled += 1,
            other => panic!("stream ended with {other}"),
        }
    }
    assert_eq!(done + cancelled, n_threads, "every stream terminates");
    assert!(done > 0, "uncancelled streams must finish");

    // Nothing may linger: queues empty, KV fully freed, and the edge
    // saw every connection out.
    let s = poll_stats(&addr, Duration::from_secs(20), |s| {
        s.running == 0 && s.waiting == 0 && s.kv_used_tokens == 0
            && s.edge_inflight == 0
    });
    assert_eq!(s.running, 0, "{s:?}");
    assert_eq!(s.waiting, 0, "{s:?}");
    assert_eq!(s.kv_used_tokens, 0, "{s:?}");
    assert_eq!(s.edge_inflight, 0, "{s:?}");
    assert_eq!(s.finished + s.cancelled, n_threads as u64, "{s:?}");
    server.shutdown();
}
