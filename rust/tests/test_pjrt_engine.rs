//! PJRT-path integration: load the real AOT artifacts, run the engine
//! through the scheduler, and check the full three-layer contract —
//! greedy decoding determinism, slot isolation, bucket migration, and the
//! serving loop end to end.
//!
//! These tests need `artifacts/` (run `make artifacts`); they are skipped
//! with a notice when it is missing so `cargo test` stays green in a bare
//! checkout.

use dynabatch::config::{PolicyKind, SchedulerConfig};
use dynabatch::engine::pjrt::PjrtEngine;
use dynabatch::engine::{DecodeWork, Engine, StepPlan};
use dynabatch::request::Request;
use dynabatch::scheduler::Scheduler;
use dynabatch::tokenizer;
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts/ — run `make artifacts`");
        None
    }
}

/// Decode-only plan over (id, position) pairs. (`StepPlan` carries a
/// private token arena now, so struct-update construction is reserved
/// for in-crate code; build through the public fields/API instead.)
fn decode_only(items: &[(u64, u32)]) -> StepPlan {
    let mut p = StepPlan::default();
    for &(id, position) in items {
        p.decodes.push(DecodeWork { id, position });
    }
    p
}

/// Drive one prompt through prefill + n decode steps, returning tokens.
fn generate(engine: &mut PjrtEngine, id: u64, prompt: &str, n: u32)
            -> Vec<i32> {
    let tokens = tokenizer::encode(prompt);
    let prompt_len = tokens.len() as u32;
    let mut plan = StepPlan::default();
    plan.push_prefill(id, &tokens, prompt_len, 0, true);
    let out = engine.step_owned(&plan).unwrap();
    let mut got: Vec<i32> =
        out.tokens.iter().filter(|(i, _)| *i == id).map(|(_, t)| *t)
            .collect();
    assert_eq!(got.len(), 1, "prefill must emit the first token");
    for k in 1..n {
        let plan = decode_only(&[(id, prompt_len + k - 1)]);
        let out = engine.step_owned(&plan).unwrap();
        got.extend(out.tokens.iter().filter(|(i, _)| *i == id)
                      .map(|(_, t)| *t));
    }
    got
}

#[test]
fn greedy_generation_is_deterministic() {
    let Some(dir) = artifacts_dir() else { return };
    let mut e1 = PjrtEngine::load(&dir).unwrap();
    let a = generate(&mut e1, 1, "hello dynamic batching", 8);
    e1.release(1);
    let b = generate(&mut e1, 2, "hello dynamic batching", 8);
    assert_eq!(a, b, "greedy decode must be deterministic");
    assert_eq!(a.len(), 8);
    // Tokens must be in-vocab.
    for &t in &a {
        assert!((0..258).contains(&t), "token {t} out of vocab");
    }
}

#[test]
fn batched_equals_solo_generation() {
    // The invariant the whole batching story rests on: a request's output
    // must not depend on what else is in the batch.
    let Some(dir) = artifacts_dir() else { return };
    let mut solo = PjrtEngine::load(&dir).unwrap();
    let want_a = generate(&mut solo, 1, "first prompt", 6);
    solo.release(1);
    let want_b = generate(&mut solo, 2, "a different prompt!", 6);

    let mut eng = PjrtEngine::load(&dir).unwrap();
    let ta = tokenizer::encode("first prompt");
    let tb = tokenizer::encode("a different prompt!");
    let (la, lb) = (ta.len() as u32, tb.len() as u32);
    let mut plan = StepPlan::default();
    plan.push_prefill(10, &ta, la, 0, true);
    plan.push_prefill(20, &tb, lb, 0, true);
    let out = eng.step_owned(&plan).unwrap();
    let mut got_a: Vec<i32> = out.tokens.iter()
        .filter(|(i, _)| *i == 10).map(|(_, t)| *t).collect();
    let mut got_b: Vec<i32> = out.tokens.iter()
        .filter(|(i, _)| *i == 20).map(|(_, t)| *t).collect();
    for k in 1..6u32 {
        let plan =
            decode_only(&[(10, la + k - 1), (20, lb + k - 1)]);
        let out = eng.step_owned(&plan).unwrap();
        got_a.extend(out.tokens.iter().filter(|(i, _)| *i == 10)
                        .map(|(_, t)| *t));
        got_b.extend(out.tokens.iter().filter(|(i, _)| *i == 20)
                        .map(|(_, t)| *t));
    }
    assert_eq!(got_a, want_a, "batched request A diverged from solo run");
    assert_eq!(got_b, want_b, "batched request B diverged from solo run");
}

#[test]
fn bucket_migration_preserves_generation() {
    // Start one long generation at bucket 1, then admit more requests to
    // force a bucket migration mid-flight; the first request's stream must
    // be unaffected.
    let Some(dir) = artifacts_dir() else { return };
    let mut solo = PjrtEngine::load(&dir).unwrap();
    let want = generate(&mut solo, 1, "migration probe", 10);

    let mut eng = PjrtEngine::load(&dir).unwrap();
    let toks = tokenizer::encode("migration probe");
    let l = toks.len() as u32;
    let mut plan = StepPlan::default();
    plan.push_prefill(1, &toks, l, 0, true);
    let out = eng.step_owned(&plan).unwrap();
    assert_eq!(eng.bucket(), 1);
    let mut got: Vec<i32> =
        out.tokens.iter().map(|(_, t)| *t).collect();
    // 4 decodes solo…
    for k in 1..5u32 {
        let plan = decode_only(&[(1, l + k - 1)]);
        got.extend(eng.step_owned(&plan).unwrap().tokens.iter()
                      .map(|(_, t)| *t));
    }
    // …admit two more requests → slot demand 3 → migrate to bucket 4.
    let t2 = tokenizer::encode("noise A");
    let t3 = tokenizer::encode("noise BB");
    let (l2, l3) = (t2.len() as u32, t3.len() as u32);
    let mut plan = decode_only(&[(1, l + 4)]);
    plan.push_prefill(2, &t2, l2, 0, true);
    plan.push_prefill(3, &t3, l3, 0, true);
    let out = eng.step_owned(&plan).unwrap();
    assert!(eng.bucket() >= 4, "bucket should have grown");
    got.extend(out.tokens.iter().filter(|(i, _)| *i == 1)
                  .map(|(_, t)| *t));
    for k in 6..10u32 {
        let plan = decode_only(&[
            (1, l + k - 1),
            (2, l2 + (k - 6)),
            (3, l3 + (k - 6)),
        ]);
        got.extend(eng.step_owned(&plan).unwrap().tokens.iter()
                      .filter(|(i, _)| *i == 1).map(|(_, t)| *t));
    }
    assert_eq!(got, want, "migration corrupted the KV stream");
}

/// Chunked-prefill directives against the real engine: the scheduler
/// splits prompts per `Directive.prefill_chunk`, and the engine's
/// slot/bucket accounting (slot pinning on first chunk, migrations,
/// shrink on release) must keep every stream byte-identical to a solo
/// whole-prompt run.
#[test]
fn scheduler_over_pjrt_honors_chunked_prefill_directives() {
    let Some(dir) = artifacts_dir() else { return };
    // Reference: whole-prompt prefill, solo.
    let prompts = ["chunked prefill probe", "second stream!", "third"];
    let mut solo = PjrtEngine::load(&dir).unwrap();
    let mut want = Vec::new();
    for (i, p) in prompts.iter().enumerate() {
        want.push(generate(&mut solo, i as u64, p, 5));
        solo.release(i as u64);
    }

    // Scheduler path with a 4-token chunk budget: every prompt needs
    // several prefill chunks before its first token.
    let mut engine = PjrtEngine::load(&dir).unwrap();
    let cfg = SchedulerConfig {
        policy: PolicyKind::MemoryAware,
        b_max: engine.max_batch(),
        chunk_tokens: Some(4),
        ..SchedulerConfig::default()
    };
    let eta = engine.max_batch() as u64 * engine.max_seq() as u64;
    let mut sched = Scheduler::new(cfg, eta, 0, 16.0, 8.0);
    for (i, p) in prompts.iter().enumerate() {
        sched.submit(Request::with_tokens(
            i as u64,
            tokenizer::encode(p),
            5,
            0.0,
        ));
    }
    let mut now = 0.0;
    let mut guard = 0;
    while sched.has_work() && guard < 1000 {
        if let Some(elapsed) = sched.step(&mut engine, now).unwrap() {
            now += elapsed;
        }
        guard += 1;
    }
    assert_eq!(sched.finished().len(), 3);
    for (i, p) in prompts.iter().enumerate() {
        let r = sched
            .finished()
            .iter()
            .find(|r| r.id == i as u64)
            .unwrap();
        assert_eq!(
            r.output_tokens, want[i],
            "chunked prefill diverged from solo run for {p:?}"
        );
    }
    // The directive stream drove real chunking: more prefill executions
    // than prompts (each prompt split into >= 2 chunks of <= 4 tokens).
    assert!(engine.stat_prefill_chunks > prompts.len() as u64,
            "chunks={}", engine.stat_prefill_chunks);
    // Slot/bucket accounting: all slots released, bucket shrunk back to
    // its smallest compiled size, and KV balanced.
    assert_eq!(engine.bucket(), 1, "release must shrink the bucket");
    sched.kv.check_invariants().unwrap();
    assert_eq!(sched.kv.used_tokens(), 0);
}

#[test]
fn scheduler_over_pjrt_serves_batch() {
    // The full L3+runtime path in-process: scheduler drives the real
    // engine with the dynamic policy until a mixed batch drains.
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = PjrtEngine::load(&dir).unwrap();
    let max_seq = engine.max_seq();
    let cfg = SchedulerConfig {
        policy: PolicyKind::MemoryAware,
        b_max: engine.max_batch(),
        ..SchedulerConfig::default()
    };
    let eta = engine.max_batch() as u64 * max_seq as u64;
    let mut sched = Scheduler::new(cfg, eta, 0, 16.0, 8.0);
    for (i, text) in ["alpha", "beta beta", "gamma gamma gamma", "delta"]
        .iter()
        .enumerate()
    {
        sched.submit(Request::with_tokens(
            i as u64,
            tokenizer::encode(text),
            6,
            0.0,
        ));
    }
    let mut now = 0.0;
    let mut guard = 0;
    while sched.has_work() && guard < 1000 {
        if let Some(elapsed) = sched.step(&mut engine, now).unwrap() {
            now += elapsed;
        }
        guard += 1;
    }
    assert_eq!(sched.finished().len(), 4);
    for r in sched.finished() {
        assert_eq!(r.generated, 6);
        assert_eq!(r.output_tokens.len(), 6);
    }
    sched.kv.check_invariants().unwrap();
}
