//! Full end-to-end over TCP with the REAL PJRT engine: client → server →
//! scheduler → PJRT decode → streamed tokens back. Skipped (with a notice)
//! when artifacts/ is missing.

use dynabatch::config::{PolicyKind, SchedulerConfig};
use dynabatch::engine::pjrt::PjrtEngine;
use dynabatch::engine::Engine;
use dynabatch::runtime::manifest::Manifest;
use dynabatch::scheduler::Scheduler;
use dynabatch::server::{client::Client, serve};
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts/ — run `make artifacts`");
        None
    }
}

#[test]
fn tcp_serving_over_real_engine() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir.join("manifest.json")).unwrap();
    let max_batch = *manifest.buckets.iter().max().unwrap();
    let cfg = SchedulerConfig {
        policy: PolicyKind::Combined,
        b_max: max_batch,
        d_sla: Some(0.5),
        ..SchedulerConfig::default()
    };
    let eta = max_batch as u64 * manifest.max_seq as u64;
    let sched = Scheduler::new(cfg, eta, 0, 16.0, 8.0);
    let dir2 = dir.clone();
    let server = serve(
        move || Ok(Box::new(PjrtEngine::load(&dir2)?) as Box<dyn Engine>),
        sched,
        "127.0.0.1:0",
    )
    .unwrap();
    let addr = server.local_addr.to_string();

    // Sequential determinism: same prompt twice → same text.
    let mut c = Client::connect(&addr).unwrap();
    let g1 = c.generate("end to end", 6).unwrap();
    let g2 = c.generate("end to end", 6).unwrap();
    assert_eq!(g1.n_tokens, 6);
    assert_eq!(g1.tokens, g2.tokens, "greedy decode must be stable");
    assert!(g1.ttft_ms >= 0.0 && g1.e2e_ms >= g1.ttft_ms);

    // Concurrent clients (exercises batching + slot isolation live).
    let handles: Vec<_> = (0..3)
        .map(|i| {
            let a = addr.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(&a).unwrap();
                let g = c.generate(&format!("client {i}"), 4).unwrap();
                (g.n_tokens, g.tokens)
            })
        })
        .collect();
    for h in handles {
        let (n, toks) = h.join().unwrap();
        assert_eq!(n, 4);
        assert_eq!(toks.len(), 4);
    }
    server.shutdown();
}
