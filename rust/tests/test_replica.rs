//! Replica-tier integration tests: routing policies balance load and pin
//! classes on live `Service` replicas, cancels and streams reach the
//! replica that owns them, a rolling restart (drain → reconfigure →
//! reopen, one replica at a time) completes with zero lost or hung
//! requests, and — on the virtual-time co-simulation behind `dynabatch
//! route` — N=2 least-loaded routing delivers ≥ 1.8× the aggregate
//! throughput of a single replica.

use dynabatch::config::presets::*;
use dynabatch::config::{PolicyKind, SchedulerConfig};
use dynabatch::driver::{run_replica_sim, SimScenario};
use dynabatch::engine::sim::SimEngine;
use dynabatch::engine::{Engine, StepOutcome, StepPlan};
use dynabatch::request::{PriorityClass, RequestId};
use dynabatch::service::{
    GenEvent, GenRequest, ReplicaSet, RoutePolicy, ServiceBuilder,
    SubmissionHandle,
};
use dynabatch::workload::{Arrival, LengthDist, Workload};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Simulated engine with a real wall-clock cost per step, so mid-flight
/// control (cancel, rolling drains) has a deterministic window to land.
struct SlowEngine {
    inner: SimEngine,
    delay: Duration,
}

impl SlowEngine {
    fn new(delay_ms: u64) -> Self {
        let model = tiny_real();
        let hw = cpu_host();
        SlowEngine {
            inner: SimEngine::new(&model, &hw),
            delay: Duration::from_millis(delay_ms),
        }
    }
}

impl Engine for SlowEngine {
    fn step(&mut self, plan: &StepPlan, out: &mut StepOutcome)
            -> anyhow::Result<()> {
        std::thread::sleep(self.delay);
        self.inner.step(plan, out)
    }

    fn release(&mut self, id: RequestId) {
        self.inner.release(id);
    }

    fn max_batch(&self) -> u32 {
        self.inner.max_batch()
    }

    fn max_seq(&self) -> u32 {
        self.inner.max_seq()
    }

    fn label(&self) -> String {
        format!("slow({})", self.inner.label())
    }
}

fn sim_set(n: usize, route: RoutePolicy, paused: bool) -> ReplicaSet {
    ReplicaSet::build(n, route, |_| {
        ServiceBuilder::new(tiny_real(), cpu_host())
            .policy(PolicyKind::Combined)
            .d_sla(0.05)
            .eta_tokens(100_000)
            .paused(paused)
    })
    .unwrap()
}

fn slow_set(n: usize, route: RoutePolicy, delay_ms: u64) -> ReplicaSet {
    ReplicaSet::build(n, route, |_| {
        ServiceBuilder::new(tiny_real(), cpu_host())
            .policy(PolicyKind::Combined)
            .d_sla(0.05)
            .eta_tokens(100_000)
            .engine(move || {
                Ok(Box::new(SlowEngine::new(delay_ms)) as Box<dyn Engine>)
            })
    })
    .unwrap()
}

fn wait_until(what: &str, ok: impl Fn() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(10);
    while !ok() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// Drain a handle to its terminal event with a bounded wait — a hung
/// stream fails the test instead of wedging it.
fn wait_done(mut h: SubmissionHandle) -> GenEvent {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        assert!(Instant::now() < deadline, "stream {} hung", h.id());
        match h.next_event_timeout(Duration::from_millis(250)) {
            Some(ev) if ev.is_terminal() => return ev,
            Some(_) | None => {}
        }
    }
}

#[test]
fn least_loaded_balances_a_skewed_backlog() {
    let set = sim_set(2, RoutePolicy::LeastLoaded, true);
    let mut handles = Vec::new();
    // Skew: four requests straight onto replica 0, bypassing the router.
    for _ in 0..4 {
        handles.push(
            set.replica(0)
                .submit(GenRequest::from_text("skew", 2))
                .unwrap(),
        );
    }
    wait_until("skew visible in the snapshot",
               || set.replica(0).snapshot().waiting == 4);
    // Routed submissions all land on the lighter replica until the
    // backlogs equalize (waiting for the published snapshot between
    // submissions, as the live router does).
    for k in 0..4u32 {
        let (i, h) = set
            .submit_routed(GenRequest::from_text("routed", 2))
            .unwrap();
        assert_eq!(i, 1, "least-loaded must pick the lighter replica");
        assert_eq!(set.replica_of(h.id()), 1);
        handles.push(h);
        wait_until("routed submission visible",
                   || set.replica(1).snapshot().waiting == k + 1);
    }
    assert_eq!(set.replica(0).snapshot().waiting, 4);
    assert_eq!(set.replica(1).snapshot().waiting, 4);
    // Everything completes once the loops run.
    set.resume();
    for h in handles {
        assert!(matches!(wait_done(h), GenEvent::Done { n_tokens: 2, .. }));
    }
    set.shutdown();
}

#[test]
fn class_pinning_reserves_replicas_for_interactive() {
    let set =
        sim_set(2, RoutePolicy::ClassPinned { reserved: 1 }, true);
    let mut handles = Vec::new();
    for _ in 0..3 {
        let (i, h) = set
            .submit_routed(
                GenRequest::from_text("chat", 2)
                    .with_class(PriorityClass::Interactive),
            )
            .unwrap();
        assert_eq!(i, 0, "interactive is pinned to the reserved replica");
        assert_eq!(set.replica_of(h.id()), 0);
        handles.push(h);
    }
    for class in [PriorityClass::Standard, PriorityClass::Batch] {
        let (i, h) = set
            .submit_routed(
                GenRequest::from_text("bulk", 2).with_class(class),
            )
            .unwrap();
        assert_eq!(i, 1, "{class:?} must avoid the reserved replica");
        assert_eq!(set.replica_of(h.id()), 1);
        handles.push(h);
    }
    // Fallback: with the unreserved replica draining, batch traffic
    // crosses into the reserved partition instead of failing.
    set.replica(1).begin_drain();
    let (i, h) = set
        .submit_routed(
            GenRequest::from_text("spill", 2)
                .with_class(PriorityClass::Batch),
        )
        .unwrap();
    assert_eq!(i, 0, "draining partition must spill to the other");
    handles.push(h);
    set.replica(1).reopen();
    set.resume();
    for h in handles {
        assert!(matches!(wait_done(h), GenEvent::Done { n_tokens: 2, .. }));
    }
    set.shutdown();
}

#[test]
fn cancel_and_stream_events_reach_the_owning_replica() {
    let set = slow_set(2, RoutePolicy::RoundRobin, 2);
    // A long-running stream (~500 steps × 2 ms of runway) and a short
    // one, landing on different replicas by round-robin.
    let (long_replica, mut long) = set
        .submit_routed(GenRequest::from_text("cancel me", 500))
        .unwrap();
    let (short_replica, short) = set
        .submit_routed(GenRequest::from_text("finish me", 4))
        .unwrap();
    assert_ne!(long_replica, short_replica, "round-robin alternates");
    assert_eq!(set.replica_of(long.id()), long_replica);
    assert_eq!(set.replica_of(short.id()), short_replica);

    // The short stream completes with its own id on every event.
    let short_id = short.id();
    match wait_done(short) {
        GenEvent::Done { id, n_tokens, .. } => {
            assert_eq!(id, short_id);
            assert_eq!(n_tokens, 4);
        }
        other => panic!("unexpected terminal {other:?}"),
    }

    // Wait until the long stream is decoding, then cancel through the
    // set front door — the cancel must route to its owning replica.
    let mut seen = 0;
    let deadline = Instant::now() + Duration::from_secs(10);
    while seen < 2 {
        assert!(Instant::now() < deadline, "no tokens streamed");
        match long.next_event_timeout(Duration::from_millis(100)) {
            Some(GenEvent::Token { id, .. }) => {
                assert_eq!(id, long.id());
                seen += 1;
            }
            Some(GenEvent::Accepted { .. }) | None => {}
            Some(other) => panic!("unexpected event {other:?}"),
        }
    }
    assert!(set.cancel(long.id()), "cancel must reach the replica");
    match wait_done(long) {
        GenEvent::Cancelled { id } => {
            assert_eq!(set.replica_of(id), long_replica);
        }
        other => panic!("expected cancellation, got {other:?}"),
    }
    // The owning replica's accounting shows the freed blocks; the other
    // replica was never involved.
    wait_until("cancel accounted", || {
        let s = set.replica(long_replica).snapshot();
        s.cancelled == 1 && s.kv_used_tokens == 0
    });
    assert_eq!(set.replica(short_replica).snapshot().cancelled, 0);
    set.shutdown();
}

/// The rotation acceptance test: a rolling restart under live traffic
/// completes with zero lost or hung requests — every submission the set
/// accepted reaches `Done` with its full budget, while the rotation
/// drains, reconfigures and reopens each replica in turn.
#[test]
fn rolling_restart_loses_and_hangs_nothing() {
    let set = Arc::new(slow_set(2, RoutePolicy::LeastLoaded, 1));
    let producer = {
        let set = set.clone();
        std::thread::spawn(move || {
            let mut handles = Vec::new();
            for k in 0..40 {
                // The router skips the draining replica, so submissions
                // keep succeeding throughout the rotation.
                let h = set
                    .submit(GenRequest::from_text(&format!("req {k}"), 4))
                    .expect("set must accept work during the rotation");
                handles.push(h);
                std::thread::sleep(Duration::from_millis(3));
            }
            handles
        })
    };
    // Let traffic build, then rotate the whole set onto a new
    // controller while the producer keeps submitting.
    std::thread::sleep(Duration::from_millis(25));
    let labels = set
        .rolling_restart(Some(&PolicyKind::StaticFixed { batch: 4 }))
        .unwrap();
    assert_eq!(labels, vec!["static-fixed:4", "static-fixed:4"]);

    let handles = producer.join().unwrap();
    assert_eq!(handles.len(), 40, "every submission was accepted");
    for h in handles {
        match wait_done(h) {
            GenEvent::Done { n_tokens, .. } => assert_eq!(n_tokens, 4),
            other => panic!("request lost in rotation: {other:?}"),
        }
    }
    // Post-rotation: both replicas reopened on the new controller and
    // the set still serves.
    for snap in set.snapshots() {
        assert!(!snap.draining, "rotation must reopen every replica");
        assert_eq!(snap.controller, "static-fixed:4");
        assert_eq!(snap.reconfigs, 1);
    }
    let h = set.submit(GenRequest::from_text("after", 3)).unwrap();
    assert!(matches!(wait_done(h), GenEvent::Done { n_tokens: 3, .. }));
    set.shutdown();
}

/// The scaling acceptance test, on the deterministic virtual-time
/// co-simulation behind `dynabatch route`: two replicas under
/// least-loaded routing deliver ≥ 1.8× the aggregate throughput of one,
/// with the load split evenly.
#[test]
fn route_two_replicas_reach_1_8x_aggregate_throughput() {
    let model = pangu_7b();
    let hardware = node_for(&model);
    let s = SimScenario {
        model,
        hardware,
        sched: SchedulerConfig {
            policy: PolicyKind::StaticFixed { batch: 8 },
            ..SchedulerConfig::default()
        },
        workload: Workload {
            name: "route-acceptance".into(),
            arrival: Arrival::AllAtOnce,
            prompt: LengthDist::Fixed(64),
            output: LengthDist::Fixed(64),
            n_requests: 208,
            seed: 7,
            prefix: None,
            length_mix: None,
        },
        eta_tokens_override: None,
        swap_tokens: 0,
    };
    let one = run_replica_sim(&s, 1, &RoutePolicy::LeastLoaded).unwrap();
    let two = run_replica_sim(&s, 2, &RoutePolicy::LeastLoaded).unwrap();
    assert_eq!(one.aggregate.n_requests, 208);
    assert_eq!(two.aggregate.n_requests, 208, "no request lost in routing");
    assert_eq!(two.aggregate.output_tokens, 208 * 64);
    assert!(two.max_token_share() < 0.55,
            "least-loaded must split evenly: share {}",
            two.max_token_share());
    let speedup = two.aggregate.throughput / one.aggregate.throughput;
    assert!(speedup >= 1.8,
            "aggregate throughput must scale: {:.0} vs {:.0} tok/s \
             ({speedup:.2}x)",
            two.aggregate.throughput, one.aggregate.throughput);
}
