//! No-op stand-in for the `xla` PJRT bindings, used when the real crate
//! is not resolvable (offline registry). Mirrors the API surface the
//! dynabatch runtime consumes; every constructor fails with a clear
//! error, so callers gate cleanly ("PJRT runtime not available") while
//! everything that doesn't touch PJRT — the simulator, scheduler,
//! service and server — works unchanged.
//!
//! Swap in the real bindings via the root Cargo.toml to run the AOT
//! TinyGPT artifacts for real.

use std::fmt;
use std::path::Path;

/// Error type matching how the runtime consumes it (`Display` into
/// `anyhow!`).
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable() -> Error {
    Error(
        "PJRT runtime not available: dynabatch was built against the \
         vendored xla stub (rust/xla-stub). Point the `xla` dependency \
         at the real bindings to enable the real engine (see Cargo.toml \
         and DESIGN.md)"
            .to_string(),
    )
}

type Result<T> = std::result::Result<T, Error>;

/// Parsed HLO module (stub: construction always fails).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self> {
        Err(unavailable())
    }
}

/// XLA computation handle.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        // Unreachable in practice: no HloModuleProto can exist.
        XlaComputation { _private: () }
    }
}

/// Device-resident buffer (stub: never constructible).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Host-side literal.
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}

/// Compiled executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer])
                     -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// PJRT client (stub: `cpu()` always fails, which is the single gate —
/// nothing downstream can be reached without one).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn compile(&self, _comp: &XlaComputation)
                   -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_gate_reports_unavailable() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("PJRT runtime not available"));
        assert!(HloModuleProto::from_text_file("/nonexistent").is_err());
    }
}
