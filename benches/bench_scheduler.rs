//! Scheduler hot-loop benchmark: steps/sec of the control loop at
//! serving batch sizes, current slab/phase-indexed layout vs the
//! preserved pre-overhaul baseline (`dynabatch::benchsched::legacy`).
//!
//! `DYNABATCH_BENCH_QUICK=1` shrinks the workload for CI smoke runs; the
//! `dynabatch bench-sched` subcommand emits the same measurements as
//! `BENCH_scheduler.json` for the checked-in perf trajectory.
use dynabatch::benchkit::Table;
use dynabatch::benchsched::bench_point;

fn main() {
    let quick = std::env::var("DYNABATCH_BENCH_QUICK").is_ok();
    let n = if quick { 500 } else { 10_000 };
    let mut t = Table::new(
        &format!("scheduler hot loop — {n} requests, sim engine"),
        &["b_t", "steps", "steps/s", "ns/step", "legacy steps/s",
          "speedup"],
    );
    for b in [32u32, 256, 1024] {
        let p = bench_point(b, n);
        assert_eq!(p.finished, n, "b={b}: run must drain");
        assert_eq!(p.legacy_finished, n, "b={b}: legacy must drain");
        t.row(vec![
            p.b_t.to_string(),
            p.steps.to_string(),
            format!("{:.0}", p.steps_per_sec()),
            format!("{:.0}", p.ns_per_step()),
            format!("{:.0}", p.legacy_steps_per_sec()),
            format!("{:.1}x", p.speedup()),
        ]);
    }
    t.print();
}
