//! Regenerates Table I (throughput: static vs dynamic, infinite arrivals).
//! Full scale: `cargo bench --bench bench_table1`; quick: set
//! DYNABATCH_BENCH_QUICK=1 (0.2×).
use dynabatch::experiments::table1;

fn main() {
    let quick = std::env::var("DYNABATCH_BENCH_QUICK").is_ok();
    let scale = if quick { 0.2 } else { 1.0 };
    let t0 = std::time::Instant::now();
    let rows = table1::run(scale).expect("table1");
    table1::render(&rows).print();
    println!("(scale {scale}, wallclock {:.1}s)", t0.elapsed().as_secs_f64());
}
