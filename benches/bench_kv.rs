//! KV block manager hot-path micro-benches (allocate/grow/free cycles at
//! serving scale, can_grow probes).
use dynabatch::benchkit::Bench;
use dynabatch::kv::KvBlockManager;

fn main() {
    let mut b = Bench::new("kv block manager");

    b.bench("alloc+grow64+free (1 req)", || {
        let mut m = KvBlockManager::new(1_000_000, 16, 0);
        m.allocate(1, 128).unwrap();
        for _ in 0..64 {
            m.grow(1, 1).unwrap();
        }
        m.free(1).unwrap();
    });

    let mut m = KvBlockManager::new(10_000_000, 16, 0);
    for id in 0..256u64 {
        m.allocate(id, 300).unwrap();
    }
    b.bench_units("grow 256 live reqs by 1", Some((256.0, "grow")), || {
        // Recycle when the pool runs low so long bench runs don't exhaust.
        if m.free_blocks() < 256 {
            for id in 0..256u64 {
                m.free(id).unwrap();
                m.allocate(id, 300).unwrap();
            }
        }
        for id in 0..256u64 {
            m.grow(id, 1).unwrap();
        }
    });
    b.bench_units("can_grow probe x256", Some((256.0, "probe")), || {
        for id in 0..256u64 {
            std::hint::black_box(m.can_grow(id, 1));
        }
    });
    b.bench("utilization gauge", || {
        std::hint::black_box(m.used_tokens());
    });
    b.report();
}
