//! Ablation suite: Alg.1 linear vs exact (paper future-work §1), decision
//! interval, ε_M, preemption mode, α/δ, and the RLHF-sampling extension.
use dynabatch::experiments::ablations;

fn main() {
    let quick = std::env::var("DYNABATCH_BENCH_QUICK").is_ok();
    let n = if quick { 120 } else { 500 };
    ablations::linear_vs_exact(n).unwrap().print();
    ablations::interval_sweep(n).unwrap().print();
    ablations::eps_mem_sweep(n).unwrap().print();
    ablations::preempt_mode(n).unwrap().print();
    ablations::alpha_delta_sweep(n).unwrap().print();
    ablations::rlhf_sampling(n).unwrap().print();
}
