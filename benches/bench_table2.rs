//! Regenerates Table II (capacity + throughput under SLA 50 ms).
use dynabatch::experiments::table2;

fn main() {
    let quick = std::env::var("DYNABATCH_BENCH_QUICK").is_ok();
    let scale = if quick { 0.3 } else { 1.0 };
    let t0 = std::time::Instant::now();
    let rows = table2::run(scale).expect("table2");
    table2::render(&rows).print();
    println!("(scale {scale}, wallclock {:.1}s)", t0.elapsed().as_secs_f64());
}
