//! Controller decision micro-bench: the paper's "barrier 2" concern is
//! that frequent batch adjustment costs more than it gains. With API v2
//! every decision also constructs a `Directive`, so this sweeps every
//! `PolicyKind` — including the combinators and the chunked wrapper —
//! through `Controller::decide` to keep directive-construction overhead
//! visible in the bench trajectory. decide() must stay effectively free
//! next to a multi-ms engine step.
use dynabatch::batching::build_controller;
use dynabatch::benchkit::Bench;
use dynabatch::config::{PolicyKind, SchedulerConfig};
use dynabatch::telemetry::Observation;

fn obs() -> Observation {
    let mut o = Observation::synthetic(100_000, 40_000, 96, 4);
    o.now = 1.0;
    o.mean_in = 128.0;
    o.mean_out = 256.0;
    o.var_in = 900.0;
    o.var_out = 4000.0;
    o.length_samples = 500;
    o.recent_decode_latency = Some(0.045);
    o.recent_decode_batch = Some(96.0);
    o.waiting = 12;
    o.waiting_by_class = [2, 8, 2];
    o.decode_latency_by_class = [Some(0.051), Some(0.045), Some(0.040)];
    o
}

fn main() {
    let mut b = Bench::new("controller.decide()");
    let kinds = vec![
        PolicyKind::StaticGreedy { max: 256 },
        PolicyKind::StaticFixed { batch: 64 },
        PolicyKind::MemoryAware,
        PolicyKind::MemoryAwareExact,
        PolicyKind::SlaFeedback,
        PolicyKind::Combined,
        PolicyKind::Min(vec![
            PolicyKind::MemoryAware,
            PolicyKind::SlaFeedback,
        ]),
        PolicyKind::Max(vec![
            PolicyKind::StaticFixed { batch: 32 },
            PolicyKind::SlaFeedback,
        ]),
        PolicyKind::ClassWeighted(vec![
            PolicyKind::SlaFeedback,
            PolicyKind::MemoryAware,
            PolicyKind::StaticFixed { batch: 16 },
        ]),
        PolicyKind::PerClassSla([Some(0.05), None, Some(0.5)]),
        PolicyKind::Min(vec![
            PolicyKind::MemoryAware,
            PolicyKind::PerClassSla([Some(0.05), None, None]),
        ]),
    ];
    for kind in kinds {
        let cfg = SchedulerConfig {
            policy: kind,
            d_sla: Some(0.05),
            ..SchedulerConfig::default()
        };
        let mut c = build_controller(&cfg);
        let o = obs();
        let label = c.label();
        b.bench(&label, || {
            std::hint::black_box(c.decide(std::hint::black_box(&o)));
        });
    }
    // The chunked wrapper adds the adaptive PD-fusion budget to every
    // directive — the most work a single decision can do today.
    let cfg = SchedulerConfig {
        policy: PolicyKind::Combined,
        d_sla: Some(0.05),
        chunk_tokens: Some(256),
        adaptive_chunk: true,
        ..SchedulerConfig::default()
    };
    let mut c = build_controller(&cfg);
    let o = obs();
    let label = c.label();
    b.bench(&label, || {
        std::hint::black_box(c.decide(std::hint::black_box(&o)));
    });
    b.report();
}
