//! Policy decision micro-bench: the paper's "barrier 2" concern is that
//! frequent batch adjustment costs more than it gains. decide() must be
//! effectively free next to a multi-ms engine step.
use dynabatch::batching;
use dynabatch::benchkit::Bench;
use dynabatch::config::{PolicyKind, SchedulerConfig};
use dynabatch::telemetry::Observation;

fn obs() -> Observation {
    Observation {
        now: 1.0,
        eta_tokens: 100_000,
        used_tokens: 40_000,
        mean_in: 128.0,
        mean_out: 256.0,
        var_in: 900.0,
        var_out: 4000.0,
        length_samples: 500,
        recent_decode_latency: Some(0.045),
        recent_decode_batch: Some(96.0),
        running_decode: 96,
        pending_prefill: 4,
        waiting: 12,
        waiting_by_class: [2, 8, 2],
    }
}

fn main() {
    let mut b = Bench::new("policy.decide()");
    for kind in [
        PolicyKind::StaticGreedy { max: 256 },
        PolicyKind::MemoryAware,
        PolicyKind::MemoryAwareExact,
        PolicyKind::SlaFeedback,
        PolicyKind::Combined,
    ] {
        let cfg = SchedulerConfig {
            policy: kind,
            d_sla: Some(0.05),
            ..SchedulerConfig::default()
        };
        let mut p = batching::build_policy(&cfg);
        let o = obs();
        let label = p.label();
        b.bench(&label, || {
            std::hint::black_box(p.decide(std::hint::black_box(&o)));
        });
    }
    b.report();
}
