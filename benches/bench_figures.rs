//! Regenerates Fig. 2 (memory timeline), Fig. 3 (D(b), Φ(b)) and
//! Fig. 4 (capacity bars + SLA sweep).
use dynabatch::experiments::figures;

fn main() {
    let quick = std::env::var("DYNABATCH_BENCH_QUICK").is_ok();

    let pts = figures::fig3(500.0, 300);
    figures::render_fig3(&pts).print();
    for (sla, b, phi) in figures::fig3_anchors(&pts) {
        println!("SLA {sla:.0} ms → b ≈ {b}, Φ ≈ {phi:.0} tok/s");
    }
    println!("(paper anchors: 50 ms → b≈100/Φ≈1900; 80 ms → b≈230/Φ≈2700)");

    let n = if quick { 150 } else { 600 };
    let r2 = figures::fig2(n).expect("fig2");
    print!("{}", figures::render_fig2(&r2));

    let probe = if quick { 150 } else { 400 };
    let sweep = if quick { vec![] } else { vec![0.030, 0.050, 0.080] };
    let r4 = figures::fig4(probe, &sweep).expect("fig4");
    print!("{}", figures::render_fig4(&r4));
}
