//! End-to-end scheduler+sim-engine stepping rate: how many virtual serving
//! iterations the coordinator sustains per wall second (L3 must never be
//! the bottleneck — the paper's engine steps are ≥ tens of ms).
use dynabatch::benchkit::Bench;
use dynabatch::config::presets::*;
use dynabatch::config::{PolicyKind, SchedulerConfig};
use dynabatch::driver::run_sim;
use dynabatch::driver::SimScenario;
use dynabatch::workload::{Arrival, LengthDist, Workload};

fn main() {
    let quick = std::env::var("DYNABATCH_BENCH_QUICK").is_ok();
    let n = if quick { 200 } else { 1319 };
    let mut b = Bench::new("end-to-end (virtual time, wallclock measured)")
        .min_iters(if quick { 1 } else { 3 });
    for policy in [PolicyKind::StaticGreedy { max: 256 },
                   PolicyKind::MemoryAware, PolicyKind::Combined] {
        let model = llama_65b();
        let hardware = node_for(&model);
        let s = SimScenario {
            model,
            hardware,
            sched: SchedulerConfig {
                policy: policy.clone(),
                d_sla: Some(0.05),
                ..SchedulerConfig::default()
            },
            workload: Workload {
                name: "bench".into(),
                arrival: Arrival::AllAtOnce,
                prompt: LengthDist::around(68.4, 1024),
                output: LengthDist::around(344.5, 1024),
                n_requests: n,
                seed: 42,
                prefix: None,
                length_mix: None,
            },
            eta_tokens_override: None,
            swap_tokens: 0,
        };
        let total_tokens = (n as f64) * 344.5;
        b.bench_units(&policy.label(), Some((total_tokens, "vtok")), || {
            std::hint::black_box(run_sim(&s).unwrap());
        });
    }
    b.report();
    println!("(vtok/s = virtual generated tokens simulated per wall-second)");
}
