//! Record a workload trace to JSONL, replay it bit-identically under two
//! policies, and diff the outcomes — the reproducibility workflow.
//!
//!     cargo run --release --example trace_replay
use dynabatch::config::presets::*;
use dynabatch::config::{PolicyKind, SchedulerConfig};
use dynabatch::driver::{run_sim, SimScenario};
use dynabatch::engine::Engine;
use dynabatch::workload::{trace, Arrival, LengthDist, Workload};

fn main() -> anyhow::Result<()> {
    let dir = std::env::temp_dir().join("dynabatch_trace_example");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("bursty.jsonl");

    // 1. Record.
    let w = Workload {
        name: "bursty".into(),
        arrival: Arrival::Bursty { high: 6.0, low: 0.5, period: 20.0 },
        prompt: LengthDist::around(128.0, 1024),
        output: LengthDist::LogNormal { mu: 5.0, sigma: 0.7, min: 8,
                                        max: 1024 },
        n_requests: 300,
        seed: 7,
        prefix: None,
        length_mix: None,
    };
    trace::save(&path, &w.generate())?;
    println!("recorded {} → {}", w.name, path.display());

    // 2. Replay under both policies (identical arrivals & lengths).
    let replayed = trace::load(&path)?;
    println!("replaying {} requests:", replayed.len());
    let model = llama_65b();
    let hardware = node_for(&model);
    for policy in [PolicyKind::StaticGreedy { max: 256 },
                   PolicyKind::MemoryAware] {
        // run_sim regenerates from the workload; to replay the exact trace
        // we drive the loop directly.
        let mut engine =
            dynabatch::engine::sim::SimEngine::new(&model, &hardware);
        let eta = hardware.kv_budget(&model) / model.kv_bytes_per_token();
        let mut sched = dynabatch::scheduler::Scheduler::new(
            SchedulerConfig { policy, ..SchedulerConfig::default() },
            eta, 0, 128.0, 150.0);
        sched.retain_full_traces(); // exact percentiles for the diff
        let mut clock = dynabatch::sim::VirtualClock::new();
        dynabatch::driver::run_loop(&mut sched, &mut engine, &mut clock,
                                    replayed.clone(), 10_000_000)?;
        use dynabatch::sim::Clock;
        let makespan = clock.now();
        let m = dynabatch::metrics::RunMetrics::compute(
            sched.controller_label(), sched.finished(), &sched.stats,
            &sched.decode_latencies.to_vec(), makespan,
            engine.utilization());
        println!("  {:28} {:6.0} tok/s, preempts {:4}, tbt p95 {:5.1} ms",
                 m.policy, m.throughput, m.preemptions, m.tbt_p95 * 1e3);
    }
    Ok(())
}
