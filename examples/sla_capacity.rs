//! SLA-constrained capacity (Table II / Fig. 4 mechanics): how many qps a
//! deployment sustains while keeping p95 decode latency within D_SLA, with
//! static vs dynamic (min(Alg.1, Alg.2)) batching.
//!
//!     cargo run --release --example sla_capacity [d_sla_ms]
use dynabatch::config::presets::*;
use dynabatch::config::{PolicyKind, SchedulerConfig};
use dynabatch::driver::{capacity_search, SimScenario};
use dynabatch::experiments::with_mha_kv;
use dynabatch::workload::{Arrival, LengthDist, Workload};

fn main() -> anyhow::Result<()> {
    let d_sla_ms: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(50.0);
    let d_sla = d_sla_ms / 1e3;
    let model = with_mha_kv(llama3_70b());
    let hardware = node_for(&model);
    let base = SimScenario {
        model,
        hardware,
        sched: SchedulerConfig {
            d_sla: Some(d_sla),
            ..SchedulerConfig::default()
        },
        workload: Workload {
            name: "sla".into(),
            arrival: Arrival::Poisson { rate: 1.0 },
            prompt: LengthDist::around(256.6, 2048),
            output: LengthDist::around(61.5, 2048),
            n_requests: 300,
            seed: 43,
            prefix: None,
            length_mix: None,
        },
        eta_tokens_override: None,
        swap_tokens: 0,
    };
    println!("capacity search at D_SLA = {d_sla_ms:.0} ms (p95 decode):");
    for policy in [PolicyKind::StaticGreedy { max: 256 },
                   PolicyKind::Combined] {
        let mut s = base.clone();
        s.sched.policy = policy;
        let cap = capacity_search(&s, d_sla, s.sched.eps_d, 95.0, 200, 0.1)?;
        println!(
            "  {:28} capacity {:5.1} qps  (throughput {:6.0} tok/s, \
             tbt_p95 {:5.1} ms)",
            cap.at_capacity.policy,
            cap.capacity_qps,
            cap.at_capacity.throughput,
            cap.at_capacity.tbt_p95 * 1e3
        );
    }
    println!("(paper Fig. 4: static 5.4 qps → dynamic 6.6 qps, +22%)");
    Ok(())
}
