//! The fleet layer in one sitting: a heterogeneous replica set
//! (baseline + two economy nodes, each deployed under a
//! `ReplicaProfile`) wrapped in a `Fleet` — submit mixed-class traffic,
//! scale down mid-run with zero loss (the parked replica drains, the
//! router keeps dispatching to the rest), scale back up, then hand the
//! fleet to the SLA autoscaler and watch its directive log.
//!
//!     cargo run --release --example fleet_quickstart
use dynabatch::config::presets::*;
use dynabatch::config::{FleetPolicyKind, PolicyKind};
use dynabatch::service::{
    Fleet, GenRequest, PriorityClass, ReplicaSet, RoutePolicy,
    ServiceBuilder,
};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    // 1. Three pangu-7B replicas behind a least-loaded router, each
    //    deployed under a catalogue profile: one baseline node and two
    //    economy nodes (0.7x speed at 0.55x cost). The profile scales
    //    each replica's KV budget and timing and is what the fleet's
    //    cost accounting bills.
    let profiles = vec![
        profile_by_name("baseline").unwrap(),
        profile_by_name("economy").unwrap(),
        profile_by_name("economy").unwrap(),
    ];
    let mk_profiles = profiles.clone();
    let set = ReplicaSet::build(3, RoutePolicy::LeastLoaded, |i| {
        let model = pangu_7b();
        let hardware = node_for(&model);
        ServiceBuilder::new(model, hardware)
            .policy(PolicyKind::Combined)
            .priors(16.0, 32.0)
            .profile(mk_profiles[i].clone())
    })?;
    let fleet = Fleet::new(Arc::new(set), profiles,
                           FleetPolicyKind::Manual)?;

    // 2. Mixed-class traffic. Handles are collected so the zero-loss
    //    property of the scale-down is checkable at the end.
    let mut handles = Vec::new();
    for k in 0..12 {
        let class = match k % 3 {
            0 => PriorityClass::Interactive,
            1 => PriorityClass::Standard,
            _ => PriorityClass::Batch,
        };
        handles.push(fleet.set().submit(
            GenRequest::from_text(&format!("fleet job {k}"), 16)
                .with_class(class),
        )?);
    }

    // 3. Scale down under load: the most expensive replica (the
    //    baseline node) parks — it drains its accepted requests to
    //    completion while the router routes new work to the economy
    //    nodes. Nothing accepted is lost.
    let live = fleet.scale(2)?;
    println!("scaled down: {live} live replica(s)");
    let s = fleet.stats();
    println!("parked={:?} profiles={:?}", s.parked, s.profiles);

    // 4. Scale back up (cheapest parked replica reopens first) and keep
    //    serving.
    let live = fleet.scale(3)?;
    println!("scaled up: {live} live replica(s)");
    handles.push(fleet.set().submit(
        GenRequest::from_text("post-scale request", 8)
            .with_class(PriorityClass::Interactive),
    )?);

    // 5. Every accepted request finishes — the mid-run scale-down shed
    //    nothing.
    for h in handles {
        let c = h.wait()?;
        println!("request {} finished with {} tokens", c.id, c.n_tokens);
    }

    // 6. Hand the fleet to the SLA autoscaler. Under `serve_fleet` a
    //    background thread ticks it every `decide_interval`; here the
    //    ticks are driven by hand so the directive log is deterministic
    //    to read. An idle fleet sits over the retire band, so after the
    //    dwell streak the autoscaler starts parking expensive replicas.
    fleet.set_policy(FleetPolicyKind::parse(
        "autoscale(spawn=12,retire=2,dwell=2,interval=0.25,cool=0,\
         min=1,max=3)",
    )?)?;
    println!("policy now: {} (tick every {}s)",
             fleet.policy_label(),
             fleet.decide_interval().unwrap_or(0.0));
    for t in 0..6 {
        fleet.tick(t as f64 * 0.25)?;
    }
    let s = fleet.stats();
    println!("after {} ticks: live={} parked={:?}", s.ticks, s.live,
             s.parked);
    for e in &s.log {
        println!("  t={:.2} {} applied={}", e.at, e.directive, e.applied);
    }

    // 7. Per-replica attribution: profile, relative cost and the live
    //    per-class TTFT p95 that feeds TTFT-driven autoscaling.
    for (i, snap) in fleet.set().snapshots().iter().enumerate() {
        println!(
            "replica {i} [{}] cost_unit={:.2} finished={} \
             interactive ttft p95={:.1}ms",
            snap.profile,
            snap.cost_unit,
            snap.finished,
            snap.class_ttft_p95[PriorityClass::Interactive.rank()] * 1e3,
        );
    }
    fleet.set().shutdown();
    Ok(())
}
