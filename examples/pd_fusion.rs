//! PD fusion (chunked prefill) with adaptive chunk sizing — Table II
//! row 3: the same SLA feedback loop drives the prefill token budget, so
//! long prompts stop blowing decode latency through mixed steps.
//!
//!     cargo run --release --example pd_fusion
use dynabatch::config::presets::*;
use dynabatch::config::{PolicyKind, SchedulerConfig};
use dynabatch::driver::{run_sim, SimScenario};
use dynabatch::experiments::with_mha_kv;
use dynabatch::workload::{Arrival, LengthDist, Workload};

fn main() -> anyhow::Result<()> {
    let model = with_mha_kv(llama3_70b());
    let hardware = node_for(&model);
    let base = SimScenario {
        model,
        hardware,
        sched: SchedulerConfig {
            policy: PolicyKind::Combined,
            d_sla: Some(0.05),
            ..SchedulerConfig::default()
        },
        workload: Workload {
            name: "pd-fusion".into(),
            arrival: Arrival::Poisson { rate: 2.0 },
            prompt: LengthDist::around(256.6, 2048),
            output: LengthDist::around(447.5, 2048),
            n_requests: 400,
            seed: 44,
            prefix: None,
            length_mix: None,
        },
        eta_tokens_override: None,
        swap_tokens: 0,
    };
    println!("LLaMA3-70B, Poisson 2 qps, D_SLA 50 ms (p95):");
    for (label, chunk, adaptive) in [
        ("segregated prefill (vLLM v0)", None, false),
        ("PD fusion, static chunk 256", Some(256u32), false),
        ("PD fusion, adaptive chunk   ", Some(256u32), true),
    ] {
        let mut s = base.clone();
        s.sched.chunk_tokens = chunk;
        s.sched.adaptive_chunk = adaptive;
        let m = run_sim(&s)?;
        println!(
            "  {label}:  tbt p95 {:5.1} ms  (mean {:5.1})  ttft p95 {:5.2} s \
             throughput {:6.0} tok/s",
            m.tbt_p95 * 1e3, m.tbt_mean * 1e3, m.ttft_p95, m.throughput
        );
    }
    Ok(())
}
