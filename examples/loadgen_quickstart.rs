//! The serving edge under open-loop load in one sitting: self-host a
//! simulated replica behind the event-loop server, drive it with a
//! fixed-seed Poisson arrival schedule over real sockets, read the
//! BENCH_server.json-style report, then shrink the edge caps and watch
//! the server shed with typed overload frames instead of queueing.
//!
//!     cargo run --release --example loadgen_quickstart
use dynabatch::loadgen::{run, LoadgenConfig};
use dynabatch::server::EdgeConfig;
use dynabatch::workload::Arrival;

fn main() -> anyhow::Result<()> {
    // 1. Open-loop: arrivals fire on the fixed-seed schedule whether or
    //    not earlier requests finished — the schedule never adapts to
    //    the server, which is what makes overload observable at all.
    let cfg = LoadgenConfig {
        arrival: Arrival::Poisson { rate: 60.0 },
        duration_s: 1.5,
        seed: 7,
        max_new_tokens: 4,
        ..LoadgenConfig::default()
    };
    let r = run(&cfg)?;
    println!(
        "healthy edge: {} arrivals, {} done, {} shed, {:.0} conn/s",
        r.n_arrivals, r.done, r.overloaded, r.conn_per_s
    );
    println!("  accept-to-first-byte p95 = {:.2} ms, e2e p95 = {:.2} ms",
             r.accept_to_first_byte.p95 * 1e3, r.e2e.p95 * 1e3);

    // 2. Same seed → bit-identical schedule (the report pins it).
    let again = run(&cfg)?;
    assert_eq!(r.schedule_hash, again.schedule_hash);
    println!("schedule hash {:016x} reproduced exactly", r.schedule_hash);

    // 3. Starve the edge: two in-flight streams max, paced engine, and
    //    a burst on top. Excess arrivals get a typed overload frame
    //    *before* the scheduler ever sees them — the queue cannot grow.
    let tiny = LoadgenConfig {
        arrival: Arrival::Bursty { high: 150.0, low: 10.0, period: 0.3 },
        duration_s: 1.0,
        seed: 11,
        max_new_tokens: 8,
        edge: Some(EdgeConfig { max_inflight: 2, ..EdgeConfig::default() }),
        host_step_delay_ms: 2,
        ..LoadgenConfig::default()
    };
    let s = run(&tiny)?;
    println!(
        "starved edge: {} launched, {} done, {} shed ({:.0}% shed rate), \
         {} hung",
        s.launched, s.done, s.overloaded, s.shed_rate * 100.0, s.hung
    );

    // 4. The full report is the same JSON `dynabatch loadgen` writes to
    //    BENCH_server.json (config/schedule/results deterministic for a
    //    fixed seed; timing is wall-clock).
    println!("{}", s.to_json(&tiny).to_string_pretty());
    Ok(())
}
