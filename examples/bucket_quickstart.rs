//! Bucketed-batching quickstart: a long-tail (bimodal) prompt mix run
//! twice under the padded-prefill cost model — once planning prefills
//! as one flat group padded to the step's longest prompt, once grouped
//! into geometric length buckets (`SchedulerConfig::buckets`) so short
//! prompts only pad to their bucket ceiling.
//!
//!     cargo run --release --example bucket_quickstart
use dynabatch::config::presets::*;
use dynabatch::config::{PolicyKind, SchedulerConfig};
use dynabatch::driver::{run_sim, SimScenario};
use dynabatch::workload::{Arrival, LengthDist, LengthMix, Workload};

fn main() -> anyhow::Result<()> {
    let model = pangu_7b();
    let hardware = node_for(&model);

    // 80% short chat turns (16-32 tokens), 20% long documents
    // (~1024 tokens): the mix where flat padding hurts most, because
    // one long prompt in a step inflates every short one to its size.
    let workload = Workload {
        name: "bucket-quickstart".into(),
        arrival: Arrival::AllAtOnce,
        prompt: LengthDist::Fixed(128), // nominal; the mix draws lengths
        output: LengthDist::Fixed(8),
        n_requests: 64,
        seed: 17,
        prefix: None,
        length_mix: Some(LengthMix::bimodal(16, 32, 1024.0, 0.2, 2048)),
    };
    println!("model: {} — 80/20 short/long prompt mix, padded prefill \
              cost model", model.name);

    for buckets in [0u32, 4] {
        let s = SimScenario {
            model: model.clone(),
            hardware: hardware.clone(),
            sched: SchedulerConfig {
                policy: PolicyKind::StaticGreedy { max: 256 },
                buckets,
                bucket_base: 64,
                padded_prefill: true,
                ..SchedulerConfig::default()
            },
            workload: workload.clone(),
            eta_tokens_override: Some(200_000),
            swap_tokens: 0,
        };
        let m = run_sim(&s)?;
        let waste = m
            .padding_waste
            .map(|w| format!("{:.0}%", w * 100.0))
            .unwrap_or_else(|| "-".into());
        println!(
            "buckets={}  {:7.0} tok/s  makespan {:6.2}s  padding waste {}",
            buckets, m.throughput, m.makespan, waste
        );
    }
    println!("\nBucketing pads each prefill group only to its bucket \
              ceiling instead of the\nstep-wide maximum, so the short \
              tail stops paying for the long one. See\n`dynabatch \
              bucket` for the fixed-seed throughput regression.");
    Ok(())
}
