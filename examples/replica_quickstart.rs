//! The replica tier in one sitting: two simulated `Service` replicas
//! behind a least-loaded router — submit across priority classes, stream
//! one request, read the per-replica attribution off the snapshots, then
//! perform a rolling restart (drain → hot-swap controller → reopen, one
//! replica at a time) and keep serving through it.
//!
//!     cargo run --release --example replica_quickstart
use dynabatch::config::presets::*;
use dynabatch::config::PolicyKind;
use dynabatch::service::{
    GenEvent, GenRequest, PriorityClass, ReplicaSet, RoutePolicy,
    ServiceBuilder,
};

fn main() -> anyhow::Result<()> {
    // 1. Two pangu-7B replicas (each its own engine loop, scheduler and
    //    KV pool) behind one front door.
    let set = ReplicaSet::build(2, RoutePolicy::LeastLoaded, |_| {
        let model = pangu_7b();
        let hardware = node_for(&model);
        ServiceBuilder::new(model, hardware)
            .policy(PolicyKind::Combined)
            .d_sla(0.05)
            .priors(16.0, 32.0)
    })?;

    // 2. Submissions route by live backlog; the handle's id encodes the
    //    owning replica (ids are namespaced per replica).
    let mut streamed = set.submit(
        GenRequest::from_text("tell me about replica routing", 24)
            .with_class(PriorityClass::Interactive),
    )?;
    println!("streaming request {} on replica {}",
             streamed.id(), set.replica_of(streamed.id()));
    let mut background = Vec::new();
    for k in 0..6 {
        let (replica, handle) = set.submit_routed(
            GenRequest::from_text(&format!("background job {k}"), 16)
                .with_class(PriorityClass::Batch),
        )?;
        println!("request {} routed to replica {replica}", handle.id());
        background.push(handle);
    }

    // 3. Stream the interactive request to completion.
    let mut tokens = 0;
    while let Some(ev) = streamed
        .next_event_timeout(std::time::Duration::from_secs(10))
    {
        match ev {
            GenEvent::Token { .. } => tokens += 1,
            GenEvent::Done { id, n_tokens, ttft, e2e, .. } => {
                println!(
                    "request {id}: {n_tokens} tokens \
                     (streamed {tokens}), ttft={:.1}ms e2e={:.1}ms",
                    ttft * 1e3, e2e * 1e3
                );
                break;
            }
            GenEvent::Error { id, message } => {
                anyhow::bail!("request {id} failed: {message}");
            }
            _ => {}
        }
    }

    // 4. Rolling restart under traffic: each replica drains (the router
    //    keeps dispatching to the other), hot-swaps its controller, and
    //    rejoins — no accepted request is lost.
    let labels =
        set.rolling_restart(Some(&PolicyKind::parse("min(alg1,alg2)")?))?;
    println!("rolling restart done; controllers now: {labels:?}");
    for handle in background {
        let c = handle.wait()?;
        println!("request {} finished with {} tokens across the rotation",
                 c.id, c.n_tokens);
    }

    // 5. Per-replica attribution + the set aggregate.
    for (i, snap) in set.snapshots().iter().enumerate() {
        println!(
            "replica {i}: finished={} steps={} controller={} draining={}",
            snap.finished, snap.steps, snap.controller, snap.draining
        );
    }
    let agg = set.aggregate_snapshot();
    println!("set aggregate: finished={} (controller: {})",
             agg.finished, agg.controller);
    let post = set.submit(GenRequest::from_text("still serving", 8))?;
    println!("post-rotation request {} got {} tokens",
             post.id(), post.wait()?.n_tokens);
    set.shutdown();
    Ok(())
}
