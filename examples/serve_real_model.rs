//! END-TO-END driver (the required real-workload example): load the
//! AOT-compiled TinyGPT artifacts and serve batched requests through the
//! full rust stack — `ServiceBuilder` → TCP frontend (protocol v2) →
//! continuous-batching scheduler (dynamic policy, class-weighted
//! admission) → PJRT engine with device-resident KV state — and report
//! latency/throughput per priority class.
//!
//!     make artifacts && cargo run --release --example serve_real_model
use dynabatch::config::{presets, PolicyKind, SchedulerConfig};
use dynabatch::engine::pjrt::PjrtEngine;
use dynabatch::engine::Engine;
use dynabatch::request::PriorityClass;
use dynabatch::runtime::manifest::Manifest;
use dynabatch::server::client::{Client, GenOptions};
use dynabatch::server::serve_service;
use dynabatch::service::ServiceBuilder;
use std::path::PathBuf;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let dir = PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "artifacts".into()));
    let manifest = Manifest::load(&dir.join("manifest.json"))?;
    let max_batch = *manifest.buckets.iter().max().unwrap();
    println!(
        "model '{}': {} params, {} layers, max_seq {}, buckets {:?}",
        manifest.model_name, manifest.param_count, manifest.n_layers,
        manifest.max_seq, manifest.buckets
    );

    let cfg = SchedulerConfig {
        policy: PolicyKind::Combined,
        b_max: max_batch,
        d_sla: Some(0.25), // 250 ms TBT target on CPU
        block_tokens: 16,
        ..SchedulerConfig::default()
    };
    let eta = max_batch as u64 * manifest.max_seq as u64;
    let dir2 = dir.clone();
    let service = ServiceBuilder::new(presets::tiny_real(),
                                      presets::cpu_host())
        .config(cfg)
        .eta_tokens(eta)
        .priors(32.0, 24.0)
        .engine(move || {
            Ok(Box::new(PjrtEngine::load(&dir2)?) as Box<dyn Engine>)
        })
        .build()?;
    let server = serve_service(service, "127.0.0.1:0")?;
    let addr = server.local_addr.to_string();
    println!("serving on {addr} (PJRT CPU, python nowhere in sight)");

    // Drive a small batched workload: 12 concurrent clients, 2 rounds,
    // interactive and batch classes interleaved.
    let prompts = [
        "the paper proposes a dynamic batching method",
        "memory-aware scheduling for LLM inference",
        "service level agreements bound decode latency",
        "KV cache growth is linear in sequence length",
    ];
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for i in 0..12 {
        let addr = addr.clone();
        let prompt = prompts[i % prompts.len()].to_string();
        let class = if i % 3 == 0 {
            PriorityClass::Interactive
        } else {
            PriorityClass::Batch
        };
        handles.push(std::thread::spawn(move || -> anyhow::Result<_> {
            let mut c = Client::connect(&addr)?;
            let opts = GenOptions { class, ..Default::default() };
            let mut stats = Vec::new();
            for round in 0..2 {
                let g = c.generate_with(&prompt, 24, &opts)?;
                stats.push((class, g.n_tokens, g.ttft_ms, g.e2e_ms));
                if i == 0 && round == 0 {
                    println!("sample output bytes: {:?}…",
                             &g.tokens[..g.tokens.len().min(8)]);
                }
            }
            Ok(stats)
        }));
    }
    let mut total_tokens = 0u64;
    let mut ttfts = Vec::new();
    let mut e2es = Vec::new();
    let mut by_class: Vec<(PriorityClass, f64)> = Vec::new();
    for h in handles {
        for (class, n, ttft, e2e) in h.join().unwrap()? {
            total_tokens += n as u64;
            ttfts.push(ttft);
            e2es.push(e2e);
            by_class.push((class, ttft));
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    ttfts.sort_by(|a, b| a.total_cmp(b));
    e2es.sort_by(|a, b| a.total_cmp(b));
    println!(
        "\n24 requests × 24 tokens in {wall:.2}s  →  {:.1} tok/s",
        total_tokens as f64 / wall
    );
    println!(
        "TTFT p50/p95: {:.0}/{:.0} ms   E2E p50/p95: {:.0}/{:.0} ms",
        ttfts[ttfts.len() / 2], ttfts[(ttfts.len() * 95) / 100],
        e2es[e2es.len() / 2], e2es[(e2es.len() * 95) / 100]
    );
    for class in [PriorityClass::Interactive, PriorityClass::Batch] {
        let xs: Vec<f64> = by_class
            .iter()
            .filter(|(c, _)| *c == class)
            .map(|(_, t)| *t)
            .collect();
        if !xs.is_empty() {
            println!("mean TTFT [{}]: {:.0} ms", class.label(),
                     xs.iter().sum::<f64>() / xs.len() as f64);
        }
    }
    println!("(recorded in EXPERIMENTS.md §End-to-end)");
    server.shutdown();
    Ok(())
}
