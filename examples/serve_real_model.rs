//! END-TO-END driver (the required real-workload example): load the
//! AOT-compiled TinyGPT artifacts, serve batched requests through the full
//! rust stack — TCP frontend → continuous-batching scheduler (dynamic
//! policy) → PJRT engine with device-resident KV state — and report
//! latency/throughput.
//!
//!     make artifacts && cargo run --release --example serve_real_model
use dynabatch::config::{PolicyKind, SchedulerConfig};
use dynabatch::engine::pjrt::PjrtEngine;
use dynabatch::engine::Engine;
use dynabatch::runtime::manifest::Manifest;
use dynabatch::scheduler::Scheduler;
use dynabatch::server::{client::Client, serve};
use std::path::PathBuf;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let dir = PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "artifacts".into()));
    let manifest = Manifest::load(&dir.join("manifest.json"))?;
    let max_batch = *manifest.buckets.iter().max().unwrap();
    println!(
        "model '{}': {} params, {} layers, max_seq {}, buckets {:?}",
        manifest.model_name, manifest.param_count, manifest.n_layers,
        manifest.max_seq, manifest.buckets
    );

    let cfg = SchedulerConfig {
        policy: PolicyKind::Combined,
        b_max: max_batch,
        d_sla: Some(0.25), // 250 ms TBT target on CPU
        block_tokens: 16,
        ..SchedulerConfig::default()
    };
    let eta = max_batch as u64 * manifest.max_seq as u64;
    let sched = Scheduler::new(cfg, eta, 0, 32.0, 24.0);
    let dir2 = dir.clone();
    let server = serve(
        move || Ok(Box::new(PjrtEngine::load(&dir2)?) as Box<dyn Engine>),
        sched,
        "127.0.0.1:0",
    )?;
    let addr = server.local_addr.to_string();
    println!("serving on {addr} (PJRT CPU, python nowhere in sight)");

    // Drive a small batched workload: 12 concurrent clients, 2 rounds.
    let prompts = [
        "the paper proposes a dynamic batching method",
        "memory-aware scheduling for LLM inference",
        "service level agreements bound decode latency",
        "KV cache growth is linear in sequence length",
    ];
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for i in 0..12 {
        let addr = addr.clone();
        let prompt = prompts[i % prompts.len()].to_string();
        handles.push(std::thread::spawn(move || -> anyhow::Result<_> {
            let mut c = Client::connect(&addr)?;
            let mut stats = Vec::new();
            for round in 0..2 {
                let g = c.generate(&prompt, 24)?;
                stats.push((g.n_tokens, g.ttft_ms, g.e2e_ms));
                if i == 0 && round == 0 {
                    println!("sample output bytes: {:?}…",
                             &g.tokens[..g.tokens.len().min(8)]);
                }
            }
            Ok(stats)
        }));
    }
    let mut total_tokens = 0u64;
    let mut ttfts = Vec::new();
    let mut e2es = Vec::new();
    for h in handles {
        for (n, ttft, e2e) in h.join().unwrap()? {
            total_tokens += n as u64;
            ttfts.push(ttft);
            e2es.push(e2e);
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    ttfts.sort_by(|a, b| a.total_cmp(b));
    e2es.sort_by(|a, b| a.total_cmp(b));
    println!(
        "\n24 requests × 24 tokens in {wall:.2}s  →  {:.1} tok/s",
        total_tokens as f64 / wall
    );
    println!(
        "TTFT p50/p95: {:.0}/{:.0} ms   E2E p50/p95: {:.0}/{:.0} ms",
        ttfts[ttfts.len() / 2], ttfts[(ttfts.len() * 95) / 100],
        e2es[e2es.len() / 2], e2es[(e2es.len() * 95) / 100]
    );
    println!("(recorded in EXPERIMENTS.md §End-to-end)");
    server.shutdown();
    Ok(())
}
