//! Quickstart: compare static vs dynamic batching on a simulated LLaMA-65B
//! deployment in a few seconds of wallclock (virtual time inside).
//!
//!     cargo run --release --example quickstart
use dynabatch::config::presets::*;
use dynabatch::config::{PolicyKind, SchedulerConfig};
use dynabatch::driver::{run_sim, SimScenario};
use dynabatch::workload::{Arrival, LengthDist, Workload};

fn main() -> anyhow::Result<()> {
    let model = llama_65b();
    let hardware = node_for(&model);
    println!("model: {} on {} (KV budget {} tokens)", model.name,
             hardware.name,
             hardware.kv_budget(&model) / model.kv_bytes_per_token());

    let workload = Workload {
        name: "quickstart".into(),
        arrival: Arrival::AllAtOnce, // the paper's "infinite arrival rate"
        prompt: LengthDist::around(68.4, 1024),
        output: LengthDist::around(344.5, 1024),
        n_requests: 400,
        seed: 42,
        prefix: None,
        length_mix: None,
    };

    for policy in [
        PolicyKind::StaticGreedy { max: 256 }, // vLLM static batching
        PolicyKind::MemoryAware,               // Algorithm 1
    ] {
        let s = SimScenario {
            model: model.clone(),
            hardware: hardware.clone(),
            sched: SchedulerConfig { policy, ..SchedulerConfig::default() },
            workload: workload.clone(),
            eta_tokens_override: None,
            swap_tokens: 0,
        };
        let m = run_sim(&s)?;
        println!(
            "{:28} {:7.0} tok/s  mean batch {:5.1}  preemptions {:4}  \
             GPU-util {:.0}%",
            m.policy, m.throughput, m.mean_batch, m.preemptions,
            m.utilization.unwrap_or(0.0) * 100.0
        );
    }
    println!("\nDynamic batching avoids the static baseline's preemption \
              storms by sizing\nthe batch from the memory bound \
              (eq. 14 of the paper). See `dynabatch table1`.");
    Ok(())
}
