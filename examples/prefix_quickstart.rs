//! Prefix-cache quickstart: the same Zipf multi-tenant burst run twice
//! on a deliberately small KV pool — once paying the full prompt per
//! request, once sharing each tenant's system prefix through the
//! ref-counted radix tree (`SchedulerConfig::prefix_cache`).
//!
//!     cargo run --release --example prefix_quickstart
use dynabatch::config::presets::*;
use dynabatch::config::{PolicyKind, SchedulerConfig};
use dynabatch::driver::{run_sim, SimScenario};
use dynabatch::workload::{Arrival, LengthDist, SharedPrefixSpec, Workload};

fn main() -> anyhow::Result<()> {
    let model = pangu_7b();
    let hardware = node_for(&model);

    // 4 tenants, each with a 512-token system prefix; requests add a
    // ~32-token private question and decode 64 tokens. The 6000-token
    // KV pool fits only a handful of full prompts — but dozens of
    // requests once the tenant prefixes are shared.
    let workload = Workload {
        name: "prefix-quickstart".into(),
        arrival: Arrival::AllAtOnce,
        prompt: LengthDist::around(32.0, 256), // private-suffix length
        output: LengthDist::Fixed(64),
        n_requests: 120,
        seed: 91,
        prefix: Some(SharedPrefixSpec {
            n_prefixes: 4,
            prefix_tokens: 512,
            zipf_s: 1.1,
        }),
        length_mix: None,
    };
    println!("model: {} — 4 tenants x 512-token shared prefix, \
              6000-token KV pool", model.name);

    for prefix_cache in [false, true] {
        let s = SimScenario {
            model: model.clone(),
            hardware: hardware.clone(),
            sched: SchedulerConfig {
                policy: PolicyKind::StaticGreedy { max: 256 },
                prefix_cache,
                ..SchedulerConfig::default()
            },
            workload: workload.clone(),
            eta_tokens_override: Some(6_000),
            swap_tokens: 0,
        };
        let m = run_sim(&s)?;
        let hit = m
            .prefix_hit_rate
            .map(|h| format!("{:.0}%", h * 100.0))
            .unwrap_or_else(|| "-".into());
        println!(
            "prefix_cache={:5}  {:7.0} tok/s  makespan {:6.1}s  \
             mean batch {:5.1}  hit-rate {}",
            prefix_cache, m.throughput, m.makespan, m.mean_batch, hit
        );
    }
    println!("\nSharing admits each tenant prefix once instead of per \
              request, so the same\npool carries a far larger decode \
              batch. See `dynabatch prefix` for the\ncapacity regression \
              against the no-sharing baseline.");
    Ok(())
}
