//! Embed the serving stack in-process through the `service` API — no TCP,
//! no CLI: build a `Service`, submit typed requests across priority
//! classes, stream events, cancel one mid-flight, and read the KV block
//! accounting off the live snapshot.
//!
//!     cargo run --release --example service_quickstart
use dynabatch::config::presets::*;
use dynabatch::config::PolicyKind;
use dynabatch::service::{
    GenEvent, GenRequest, PriorityClass, ServiceBuilder,
};
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    // A simulated pangu-7B deployment; swap `.engine(...)` in for PJRT.
    let model = pangu_7b();
    let hardware = node_for(&model);
    let service = ServiceBuilder::new(model, hardware)
        .policy(PolicyKind::Combined)
        .d_sla(0.05)
        .priors(16.0, 32.0)
        .build()?;

    // 1. Two classes submitted concurrently: the interactive request
    //    wins contended admission slots under the policy's b_t.
    let interactive = service.submit(
        GenRequest::from_text("tell me about dynamic batching", 32)
            .with_class(PriorityClass::Interactive)
            .with_deadline(5.0),
    )?;
    let batch = service.submit(
        GenRequest::from_text("background summarization job", 32)
            .with_class(PriorityClass::Batch),
    )?;

    // 2. A third request we will cancel mid-stream.
    let mut doomed = service.submit(
        GenRequest::from_text("this one gets cancelled", 512)
            .with_class(PriorityClass::Batch),
    )?;
    let doomed_id = doomed.id();

    // Stream the doomed request until its first token, then cancel.
    let mut seen_tokens = 0;
    while let Some(ev) = doomed.next_event_timeout(Duration::from_secs(10)) {
        match ev {
            GenEvent::Token { .. } => {
                seen_tokens += 1;
                if seen_tokens == 1 {
                    println!("request {doomed_id}: first token streamed — \
                              cancelling");
                    doomed.cancel();
                }
            }
            GenEvent::Cancelled { id } => {
                println!("request {id}: cancelled, KV blocks freed");
                break;
            }
            GenEvent::Done { id, n_tokens, .. } => {
                println!("request {id}: finished ({n_tokens} tokens) \
                          before the cancel landed");
                break;
            }
            _ => {}
        }
    }

    // 3. The other two run to completion; per-request latency comes back
    //    on the handle.
    for (label, handle) in [("interactive", interactive), ("batch", batch)] {
        let c = handle.wait()?;
        println!(
            "{label:12} id={} tokens={} ttft={:.1}ms e2e={:.1}ms",
            c.id, c.n_tokens, c.ttft * 1e3, c.e2e * 1e3
        );
    }

    // 4. Introspection: the snapshot exposes queue depths per class and
    //    the KV block accounting.
    let snap = service.snapshot();
    println!(
        "snapshot: finished={} cancelled={} kv_used={} tokens \
         (free blocks {}/{})",
        snap.finished, snap.cancelled, snap.kv_used_tokens,
        snap.kv_free_blocks, snap.kv_total_blocks
    );
    service.shutdown();
    Ok(())
}
