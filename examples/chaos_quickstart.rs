//! The chaos layer in one sitting: run the same fixed-seed workload
//! through three co-simulated replicas twice — once clean, once with a
//! mid-run crash plus a 4× straggler — and watch straggler detection,
//! crash re-routing and interactive hedging keep every accepted
//! request accounted for.
//!
//!     cargo run --release --example chaos_quickstart
use dynabatch::config::presets::*;
use dynabatch::config::{PolicyKind, SchedulerConfig};
use dynabatch::driver::{run_chaos_sim, Fault, FaultPlan, SimScenario};
use dynabatch::service::RoutePolicy;
use dynabatch::workload::{Arrival, LengthDist, Workload};

fn main() -> anyhow::Result<()> {
    let model = pangu_7b();
    let hardware = node_for(&model);
    let scenario = SimScenario {
        model,
        hardware,
        sched: SchedulerConfig {
            policy: PolicyKind::Combined,
            ..SchedulerConfig::default()
        },
        workload: Workload {
            name: "chaos-quickstart".into(),
            arrival: Arrival::Poisson { rate: 12.0 },
            prompt: LengthDist::around(64.0, 256),
            output: LengthDist::around(64.0, 256),
            n_requests: 120,
            seed: 42,
            prefix: None,
            length_mix: None,
        },
        eta_tokens_override: None,
        swap_tokens: 0,
    };
    let route = RoutePolicy::LeastLoaded;
    let mix = [0.5, 0.3, 0.2];

    // 1. Clean reference run: same seed, no faults — the envelope the
    //    faulted run is judged against.
    let quiet = FaultPlan { mix, ..FaultPlan::default() };
    let base = run_chaos_sim(&scenario, 3, &route, &quiet)?;

    // 2. Fault schedule: replica 2 crashes mid-run; replica 0 turns
    //    into a 4× straggler and never recovers on its own. The health
    //    tracker suspects the straggler off its decode p95s (routing
    //    then avoids it and hedges its waiting interactive work); the
    //    crash re-routes intact prompts and fails mid-decode ones with
    //    a typed terminal error — nothing hangs, nothing vanishes.
    let plan = FaultPlan {
        faults: vec![
            Fault::Crash { replica: 2, at: 3.0 },
            Fault::Slow { replica: 0, at: 1.0, factor: 4.0,
                          duration: f64::INFINITY },
        ],
        mix,
        ..FaultPlan::default()
    };
    let chaos = run_chaos_sim(&scenario, 3, &route, &plan)?;

    println!("clean   : ttft p95 = {:.1} ms, finished = {}",
             base.set.aggregate.ttft_p95 * 1e3,
             base.set.aggregate.n_requests);
    println!(
        "faulted : ttft p95 = {:.1} ms, finished = {} \
         (incl. hedge duplicates)",
        chaos.set.aggregate.ttft_p95 * 1e3,
        chaos.set.aggregate.n_requests
    );
    println!(
        "injected {} faults: crashes={} suspected={} rerouted={} \
         failed={} hedged={} hedge_wins={} duplicates_suppressed={}",
        chaos.faults_injected, chaos.crashes, chaos.suspected,
        chaos.rerouted, chaos.failed, chaos.hedged, chaos.hedge_wins,
        chaos.duplicates_suppressed
    );
    println!(
        "phase ttft p95 (pre/during/post-fault) = \
         {:.1}/{:.1}/{:.1} ms",
        chaos.phase_ttft_p95[0] * 1e3,
        chaos.phase_ttft_p95[1] * 1e3,
        chaos.phase_ttft_p95[2] * 1e3,
    );
    assert_eq!(chaos.lost, 0, "zero-loss ledger must balance");
    println!("lost = {} — every accepted request reached exactly one \
              terminal event", chaos.lost);
    Ok(())
}
